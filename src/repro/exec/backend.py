"""Pluggable execution backends for the query hot path.

The paper's time-to-first-result hinges on three per-shard primitives:

  * **bitmap intersection** — AND-reduce the index-probe postings
    (``probe_shard``),
  * **mask compaction** — positions of selected rows after the residual
    filter (``apply_filter``),
  * **group-by partial aggregation** — (count, sum, sumsq) per group code
    (``aggregate_produce``),

An :class:`ExecBackend` supplies all three behind one seam so the logical
plan stays engine- and backend-agnostic:

  * ``numpy``  — the host reference (current behavior, the parity oracle),
  * ``jax``    — dispatches through :mod:`repro.kernels.ops`, which selects
    the Pallas kernels on TPU (``pallas``), the interpreted kernel bodies
    (``interpret``), or the pure-jnp oracle (``reference``) via
    ``REPRO_KERNEL_IMPL``.

Select a backend per engine (``AdHocEngine(backend="jax")``), per session
(``Session(backend="jax")``), or globally with ``REPRO_EXEC_BACKEND``.
Bit/integer primitives are exact, so selection is byte-identical across
backends; the jax ``reference`` aggregation path runs the segment kernel
math at float64 (``enable_x64``) and accumulates in row order — bit-equal
to the numpy oracle's ``bincount`` — while ``pallas``/``interpret`` keep
the MXU's float32, the TPU deployment precision.

**Batched multi-shard ops.**  The engines dispatch *waves* of shards
(``repro.exec.batched``) through ``probe_shards`` / ``compact_masks`` /
``segment_aggregate_batched``: the jax backend pads the wave's ragged
per-shard shapes into one stacked buffer and runs **one** kernel launch
per wave (``bitmap_intersect_batched`` / ``compact_batched`` / offset
group codes into one ``segment_agg``), while the numpy base-class
implementations loop shard-by-shard over the single-shard primitives —
the loop-over-shards oracle the batched path must match byte-for-byte.

**Ragged track refine.**  The exact Tesseract pass (point-in-cover ×
time-window over ragged ``(values, row_splits)`` tracks) is the fourth
op pair on the seam: ``refine_tracks`` / ``refine_tracks_batched`` emit
the per-doc hit mask that feeds ``compact_masks``.  The numpy base class
is the vectorized host oracle (:mod:`repro.exec.refine`); the jax backend
launches the Pallas ``refine`` kernel over packed integer point buffers —
one fused launch per wave — so the last big host stage of the Tesseract
hot loop runs behind the seam too.  Ordered queries hand the op an
``edges`` DAG: the same launch min-reduces per-(doc × constraint)
**first-hit** timestamps and each edge is a strict first-hit compare
applied device-side before the mask comes back — byte-parity extends to
the first-hit table itself (``with_first_hits``).

**Fused wave dispatch.**  ``run_wave_fused`` collapses a whole wave's
probe → refine → compact → segment-agg chain into ONE device dispatch
(:mod:`repro.kernels.fused`): the numpy base class is the loop-over-stages
oracle, the jax override one jitted multi-stage pipeline with zero host
syncs between stages.  On the fused path the launch contract tightens from
⌈shards/wave⌉ launches *per primitive* to ⌈shards/wave⌉ **total** fused
dispatches per query.  Engines fall back to the per-primitive path when
the op declines or is ineligible: ``REPRO_EXEC_FUSED=0``, a backend
without ``batched_dispatch``, a residual filter (needs gathered columns
host-side), more than one refine spec, a refine spec with zero or more
than 30 constraints, a shard without a packed track, or a wave whose
tracks are all empty.  The fused *aggregation* stage additionally requires
a single dense int-key group-by with only count/sum/avg/std_dev over dense
numeric columns (``exec.batched.fused_agg_plan``) — other aggregate plans
still run the fused selection stages and aggregate host-side from the
gathered columns.  ``prefetch_wave`` stages wave *k+1*'s stacked buffers
(refine point stacks, offset group codes, value stacks) through the
``DeviceCache`` keyed entries while wave *k* computes — the async-prefetch
half of the paper's pipelined evaluation.

The jax backend additionally keeps stable per-FDb buffers (column values,
valid-doc bitmaps, spacetime postings, packed track points) device-resident
across queries — ``prime_fdb`` / :mod:`repro.exec.device_cache` — so the
selective column read (``gather_columns``) pulls from resident buffers
instead of re-uploading columns per query; repeated columns use a
device-side CSR spans-concatenate gather.

Future scaling PRs (sharded device meshes, async prefetch, GPU lowering)
plug in here: ``register_backend`` a new implementation and every engine
picks it up.
"""
from __future__ import annotations

import contextlib
import os
import threading
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..fdb.index import (bitmap_from_ids, bitmap_stack, ids_from_bitmap,
                         mask_from_bitmap)
from .refine import (FIRST_HIT_NONE, LAST_HIT_NONE, pack_constraints,
                     pack_constraints_multi, pack_track_points,
                     reduction_verdict, refine_tracks_host)


def _has_red(min_counts, dwells) -> bool:
    """True when the per-constraint reductions change the verdict — a
    non-default min count or any dwell predicate."""
    return ((min_counts is not None
             and any(int(k) != 1 for k in min_counts))
            or (dwells is not None and any(d is not None for d in dwells)))


def _segment_minmax_host(codes: np.ndarray, values: np.ndarray,
                         num_groups: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host per-group (min, max) float64 — the oracle for the fused agg
    tail's min/max slots.  Groups with no rows keep ±inf fills (dropped by
    the ``count > 0`` keep-filter downstream)."""
    codes = np.asarray(codes, dtype=np.int64)
    keep = codes >= 0
    if not keep.all():
        codes, values = codes[keep], np.asarray(values)[keep]
    v = np.asarray(values, dtype=np.float64)
    mn = np.full(num_groups, np.inf)
    mx = np.full(num_groups, -np.inf)
    np.minimum.at(mn, codes, v)
    np.maximum.at(mx, codes, v)
    return mn, mx

__all__ = ["ExecBackend", "NumpyBackend", "JaxBackend", "register_backend",
           "backend_names", "get_backend", "as_backend"]


class ExecBackend:
    """Interface every execution backend implements.

    All methods take and return **host** numpy arrays; a device-resident
    backend owns its own transfers (and may cache device buffers keyed by
    array identity).  Contracts:

      * ``intersect_bitmaps(full, bitmaps)`` → uint32 word bitmap: AND of
        ``full`` (the shard's valid-doc mask) and every probe bitmap.
      * ``select_ids(bitmap, n)`` → ascending int64 doc ids of set bits.
      * ``compact_mask(mask)`` → ascending int64 positions of True entries.
      * ``segment_aggregate(codes, values, num_groups)`` →
        ``(count[G] int64, sum[G] float64, sumsq[G] float64)`` with rows
        whose code is negative ignored.
    """

    name: str = "abstract"
    #: True when the batched ops amortize real kernel launches; engines
    #: then default to multi-shard waves.  Loop-over-shards backends keep
    #: a default wave of 1 so per-shard thread parallelism is preserved
    #: (an explicit wave=/$REPRO_EXEC_WAVE still forces wider waves).
    batched_dispatch: bool = False

    def intersect_bitmaps(self, full: np.ndarray,
                          bitmaps: Sequence[np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def select_ids(self, bitmap: np.ndarray, n: int) -> np.ndarray:
        raise NotImplementedError

    def compact_mask(self, mask: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def segment_aggregate(self, codes: np.ndarray, values: np.ndarray,
                          num_groups: int
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        raise NotImplementedError

    # -------------------------------------------------- batched (per wave)
    # Base-class implementations loop shard-by-shard over the single-shard
    # primitives: that *is* the oracle the batched overrides must match
    # byte-for-byte (ragged shard sizes, empty shards included).

    def probe_shards(self, fulls: Sequence[np.ndarray],
                     probes: Sequence[Sequence[np.ndarray]]
                     ) -> List[np.ndarray]:
        """Per-shard AND of valid-doc bitmap and probe bitmaps, one wave."""
        return [self.intersect_bitmaps(f, ps)
                for f, ps in zip(fulls, probes)]

    def compact_masks(self, masks: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Per-shard positions of True entries, one wave."""
        return [self.compact_mask(m) for m in masks]

    def segment_aggregate_batched(
            self, codes: Sequence[np.ndarray], values: Sequence[np.ndarray],
            num_groups: Sequence[int]
            ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Per-shard (count, sum, sumsq) over shard-local group codes."""
        return [self.segment_aggregate(c, v, g)
                for c, v, g in zip(codes, values, num_groups)]

    # ------------------------------------------------------- track refine
    def refine_tracks(self, batch, path: str, constraints,
                      candidates: Optional[np.ndarray] = None,
                      edges=(), with_first_hits: bool = False,
                      min_counts=None, dwells=None,
                      with_analytics: bool = False):
        """Exact Tesseract refine over the ragged track at ``path``:
        per-doc bool mask [batch.n], True iff for *every* ``(region, t0,
        t1)`` constraint some track point lies inside the region's cover
        during the window.  ``candidates`` (bool mask) restricts the docs
        considered — the result equals ``full_refine & candidates`` bit
        for bit, and feeds ``compact_masks`` directly.

        ``edges`` is the ordering DAG over the constraint list: edge
        ``(i, j)`` additionally requires the doc's **first hit** of
        constraint ``i`` (min packed timestamp among its satisfying
        points) to be strictly before its first hit of ``j`` — equal
        first hits do not count as before.  ``with_first_hits`` returns
        ``(mask, table)`` with ``table`` the uint64 [batch.n, C]
        first-hit table (``exec.refine.FIRST_HIT_NONE`` where a
        constraint never hits) — parity-checked byte-for-byte across
        backends.

        ``min_counts``/``dwells`` generalize the per-constraint verdict
        (≥ k hits; last − first ≥ d seconds — see
        ``exec.refine.refine_tracks_host``); ``with_analytics`` returns
        ``(mask, first, last, count)`` — the full reduction-table family,
        parity-checked across backends.  Host reference: vectorized
        numpy over the shard's CSR columns."""
        lat = batch[path + ".lat"]
        lng = batch[path + ".lng"]
        tt = batch[path + ".t"]
        return refine_tracks_host(lat.values, lng.values, tt.values,
                                  lat.row_splits, batch.n,
                                  list(constraints), candidates,
                                  edges=tuple(edges),
                                  with_first_hits=with_first_hits,
                                  min_counts=min_counts, dwells=dwells,
                                  with_analytics=with_analytics)

    def refine_tracks_batched(self, batches, path: str, constraints,
                              candidates_list=None, edges=(),
                              with_first_hits: bool = False,
                              min_counts=None, dwells=None,
                              with_analytics: bool = False):
        """Per-shard refine masks for one wave — the loop-over-shards
        oracle the batched overrides must match byte-for-byte.  Returns
        the mask list, ``(masks, tables)`` under ``with_first_hits``, or
        ``(masks, firsts, lasts, counts)`` under ``with_analytics``."""
        batches = list(batches)
        if candidates_list is None:
            candidates_list = [None] * len(batches)
        outs = [self.refine_tracks(b, path, constraints, cand, edges=edges,
                                   with_first_hits=with_first_hits,
                                   min_counts=min_counts, dwells=dwells,
                                   with_analytics=with_analytics)
                for b, cand in zip(batches, candidates_list)]
        if with_analytics:
            return ([o[0] for o in outs], [o[1] for o in outs],
                    [o[2] for o in outs], [o[3] for o in outs])
        if with_first_hits:
            return [m for m, _ in outs], [t for _, t in outs]
        return outs

    # -------------------------------------------- multi-query (coalesced)
    # The query-serving layer coalesces Q compatible in-flight queries
    # against ONE resident wave of shards.  Base-class implementations
    # loop query-by-query over the single-query ops — the oracle the
    # stacked overrides must match byte-for-byte per query.

    def probe_shards_multi(self, fulls: Sequence[np.ndarray],
                           probes_multi) -> List[List[np.ndarray]]:
        """Per-query wave probes: ``probes_multi[q][s]`` is query q's
        probe-bitmap list for shard s.  Returns one ``probe_shards``
        result list per query."""
        return [self.probe_shards(fulls, probes) for probes in probes_multi]

    def refine_tracks_multi(self, batches, path: str, constraints_list,
                            candidates_lists=None, edges_list=None,
                            with_first_hits: bool = False,
                            min_counts_list=None, dwells_list=None):
        """Per-query wave refine: Q queries' constraint lists against one
        wave's shared tracks.  Returns one ``refine_tracks_batched``
        result per query (mask list, or ``(masks, tables)`` under
        ``with_first_hits``).  ``min_counts_list``/``dwells_list`` carry
        each query's per-constraint reductions (or ``None``)."""
        batches = list(batches)
        n_q = len(constraints_list)
        if candidates_lists is None:
            candidates_lists = [None] * n_q
        if edges_list is None:
            edges_list = [()] * n_q
        if min_counts_list is None:
            min_counts_list = [None] * n_q
        if dwells_list is None:
            dwells_list = [None] * n_q
        return [self.refine_tracks_batched(batches, path, cons, cands,
                                           edges=edges,
                                           with_first_hits=with_first_hits,
                                           min_counts=mc, dwells=dw)
                for cons, cands, edges, mc, dw in zip(
                    constraints_list, candidates_lists, edges_list,
                    min_counts_list, dwells_list)]

    def run_wave_fused_multi(self, shards, probes_multi, refines,
                             prefetch_shards=None):
        """Q coalesced *selection* queries (no aggregation tail) through
        one wave: returns a per-query list of ``(n_cands, ids_list)``
        pairs, or ``None`` to decline (the server then runs each query
        through the single-query path).  Base implementation is the
        loop-over-queries oracle the stacked override must match
        byte-for-byte per query."""
        out = []
        for probes, rf in zip(probes_multi, refines):
            r = self.run_wave_fused(shards, probes, refine=rf, agg=None)
            if r is None:
                return None
            n_cands, ids_list, _seg = r
            out.append((n_cands, ids_list))
        return out

    # -------------------------------------------------- sketch aggregation
    def segment_hll(self, codes: np.ndarray, reg_idx: np.ndarray,
                    ranks: np.ndarray, num_groups: int,
                    num_regs: int) -> np.ndarray:
        """Grouped HyperLogLog register build: per-row ``(group code,
        register index, rank)`` triples → uint8 ``[num_groups, num_regs]``
        per-group register planes.  The reduce is a plain max with
        identity 0 (= empty register) — commutative and idempotent, so
        the result is independent of row order and of how rows are split
        across shards or partitions (the ``merge_partials`` contract for
        sketches).  Rows with negative codes are ignored.  Host
        reference: one ``np.maximum.at`` scatter."""
        regs = np.zeros((num_groups, num_regs), dtype=np.uint8)
        codes = np.asarray(codes, dtype=np.int64)
        keep = codes >= 0
        if not keep.all():
            codes = codes[keep]
            reg_idx = np.asarray(reg_idx, dtype=np.int64)[keep]
            ranks = np.asarray(ranks, dtype=np.uint8)[keep]
        np.maximum.at(regs, (codes, np.asarray(reg_idx, dtype=np.int64)),
                      np.asarray(ranks, dtype=np.uint8))
        return regs

    # -------------------------------------------------- fused wave pipeline
    def postings_bitmap(self, ids: np.ndarray, t_min: np.ndarray,
                        t_max: np.ndarray, t0: float, t1: float,
                        n_docs: int) -> np.ndarray:
        """OR doc ``ids`` into a word bitmap and prune docs whose track
        span ``[t_min, t_max]`` misses ``[t0, t1]`` — the tail of
        ``SpaceTimeIndex.lookup`` behind the seam (host reference)."""
        bm = bitmap_from_ids(np.asarray(ids, dtype=np.int64), n_docs)
        overlap = (t_min <= t1) & (t_max >= t0)
        return bm & bitmap_from_ids(
            np.nonzero(overlap)[0].astype(np.int64), n_docs)

    def run_wave_fused(self, shards, probes, refine=None, agg=None,
                       prefetch_shards=None, profile=None):
        """Whole-wave probe → refine → compact → (segment-agg) as one
        logical dispatch.  Returns ``(n_cands, ids_list, seg)``: per-shard
        pre-refine candidate counts, selected doc ids, and — when ``agg``
        (an ``exec.batched.FusedAggPlan``) is given — per-shard
        ``(group_keys, [(count, sum, sumsq) per value slot])`` partials
        over each shard's full group space.  May return ``None`` to
        decline, in which case the engine runs the per-primitive path.

        This base implementation is the loop-over-stages oracle the fused
        overrides must match byte-for-byte; ``prefetch_shards`` is a hint
        only (no-op on host backends)."""
        shards = list(shards)
        if not shards:
            return [], [], ([] if agg is not None else None)
        bms = self.probe_shards([sh.all_bitmap() for sh in shards], probes)
        masks = [mask_from_bitmap(bm, sh.n) for bm, sh in zip(bms, shards)]
        n_cands = [int(m.sum()) for m in masks]
        if refine is not None:
            masks = self.refine_tracks_batched(
                [sh.batch for sh in shards], refine.path,
                refine.constraints, masks, edges=refine.edges,
                min_counts=getattr(refine, "min_counts", None),
                dwells=getattr(refine, "dwells", None))
        ids_list = self.compact_masks(masks)
        seg = None
        if agg is not None:
            mm = tuple(getattr(agg, "minmax", ()) or ())
            seg = []
            for sh, ids in zip(shards, ids_list):
                uniq, codes, g = agg.factorize(sh, backend=self)
                if g == 0:
                    seg.append((uniq, []))
                    continue
                csel = codes[ids]
                slots = []
                for k, vp in enumerate(agg.value_paths or [None]):
                    vals = (sh.batch[vp].values[ids] if vp is not None
                            else np.zeros(ids.size))
                    slot = self.segment_aggregate(csel, vals, g)
                    if k < len(mm) and mm[k]:
                        slot = (*slot,
                                *_segment_minmax_host(csel, vals, g))
                    slots.append(slot)
                seg.append((uniq, slots))
        return n_cands, ids_list, seg

    # ------------------------------------------------------ partition layer
    def partition_context(self, part: int, num_parts: int):
        """Context manager the wave scheduler enters around one
        partition's dispatches.  Host backends have nothing to place —
        the partition layer degenerates to running the partitions'
        waves one after another on the same loop."""
        del part, num_parts
        return contextlib.nullcontext()

    def merge_partials(self, states, minmax=(), parts=None):
        """Combine per-shard segment-aggregate states across partitions
        — the partitioned Mixer combine, and the loop-over-partitions
        **oracle** mesh backends must match.

        ``states`` is a flat list of ``(uniq_keys, slots)`` pairs in
        global shard order (partitions are contiguous slices, so
        flattening per-partition results in partition order *is* shard
        order); each slot is ``(count, sum, sum_sq[, min, max])`` vectors
        over that state's own key space.  Returns ``(union_keys,
        merged_slots)`` over the sorted union key space: counts, sums and
        sums-of-squares accumulate **sequentially in states order** with
        absent groups contributing the additive identity 0 (bit-equal to
        the P=1 sequential merge), min/max planes reduce element-wise
        against ±inf, and the per-group presence masks OR (a group is
        live iff some state selected a row for it, which is exactly
        ``merged count > 0`` — counts are non-negative).

        ``minmax`` flags which value slots carry min/max planes;
        ``parts`` (per-partition state counts) is layout metadata for
        mesh-sharding backends — the host oracle just loops in order.
        """
        del parts
        live = [(np.asarray(k), list(slots)) for k, slots in states
                if len(k) and slots]
        if not live:
            return np.zeros(0, np.int64), []
        union = np.unique(np.concatenate([k for k, _ in live]))
        n_slots = max(len(slots) for _, slots in live)
        mm = tuple(minmax)
        mm = mm + (False,) * (n_slots - len(mm))
        g = union.size
        cnt = [np.zeros(g, np.int64) for _ in range(n_slots)]
        s = [np.zeros(g, np.float64) for _ in range(n_slots)]
        s2 = [np.zeros(g, np.float64) for _ in range(n_slots)]
        mn = [np.full(g, np.inf) for _ in range(n_slots)]
        mx = [np.full(g, -np.inf) for _ in range(n_slots)]
        mask = np.zeros(g, bool)
        for keys, slots in live:               # in order over states
            idx = np.searchsorted(union, keys)
            for k, st in enumerate(slots):
                # densify onto the union space, then accumulate — the
                # identical arithmetic a stacked device combine performs
                row_c = np.zeros(g, np.int64)
                row_s = np.zeros(g, np.float64)
                row_s2 = np.zeros(g, np.float64)
                row_c[idx] = np.asarray(st[0], np.int64)
                row_s[idx] = np.asarray(st[1], np.float64)
                row_s2[idx] = np.asarray(st[2], np.float64)
                cnt[k] = cnt[k] + row_c
                s[k] = s[k] + row_s
                s2[k] = s2[k] + row_s2
                if len(st) >= 5:
                    row_mn = np.full(g, np.inf)
                    row_mx = np.full(g, -np.inf)
                    row_mn[idx] = np.asarray(st[3], np.float64)
                    row_mx[idx] = np.asarray(st[4], np.float64)
                    mn[k] = np.minimum(mn[k], row_mn)
                    mx[k] = np.maximum(mx[k], row_mx)
            present = np.zeros(g, bool)
            present[idx] = np.asarray(slots[0][0]) > 0
            mask |= present
        merged = []
        for k in range(n_slots):
            slot = (cnt[k], s[k], s2[k])
            if mm[k]:
                slot = (*slot, mn[k], mx[k])
            merged.append(slot)
        return union, merged

    def prefetch_wave(self, shards, refine=None, agg=None) -> None:
        """Stage a wave's stacked buffers ahead of compute (no-op on host
        backends — there is nothing to upload)."""

    def gather_columns(self, batch, paths: Sequence[str],
                       ids: np.ndarray):
        """Selective column read of ``ids`` rows (host reference)."""
        return batch.select_paths(list(paths)).gather(ids)

    def prime_fdb(self, db) -> int:
        """Make ``db``'s stable buffers backend-resident (no-op on host)."""
        return 0

    def __repr__(self):
        return f"<ExecBackend {self.name}>"


# --------------------------------------------------------------------------
# numpy — host reference implementation (the oracle)
# --------------------------------------------------------------------------

class NumpyBackend(ExecBackend):
    name = "numpy"

    def intersect_bitmaps(self, full, bitmaps):
        bm = full
        for b in bitmaps:
            bm = bm & b
        return bm

    def select_ids(self, bitmap, n):
        return ids_from_bitmap(bitmap, n)

    def compact_mask(self, mask):
        return np.nonzero(mask)[0].astype(np.int64)

    def segment_aggregate(self, codes, values, num_groups):
        codes = np.asarray(codes, dtype=np.int64)
        keep = codes >= 0
        if not keep.all():
            codes, values = codes[keep], np.asarray(values)[keep]
        v = np.asarray(values, dtype=np.float64)
        cnt = np.bincount(codes, minlength=num_groups)[:num_groups]
        s = np.bincount(codes, weights=v, minlength=num_groups)[:num_groups]
        s2 = np.bincount(codes, weights=v * v,
                         minlength=num_groups)[:num_groups]
        return cnt.astype(np.int64), s, s2


# --------------------------------------------------------------------------
# jax — kernels.ops dispatch (pallas on TPU, interpret/reference elsewhere)
# --------------------------------------------------------------------------

class JaxBackend(ExecBackend):
    """Routes the hot loop through :mod:`repro.kernels.ops`.

    ``impl`` pins the kernel implementation (``pallas`` / ``interpret`` /
    ``reference``); default defers to ``ops.default_impl()`` per call, so
    ``REPRO_KERNEL_IMPL`` keeps working.
    """

    name = "jax"
    batched_dispatch = True

    def __init__(self, impl: Optional[str] = None):
        import jax  # container ships the jax_pallas toolchain
        import jax.numpy as jnp
        from ..kernels import fused as fused_mod
        from ..kernels import ops
        from .device_cache import DeviceCache
        self._jax, self._jnp, self._ops = jax, jnp, ops
        self._fused = fused_mod
        self.impl = impl
        self.device_cache = DeviceCache(jax)
        #: when set to a list, the fused path appends ("prefetch", n) /
        #: ("wave_done", shard_ids) markers — the prefetch-ordering tests'
        #: evidence that wave k+1 staged before wave k finished
        self.trace_events: Optional[list] = None
        # weak: a collected FDb drops out, so a new FDb reusing the same
        # address still primes, and a finalizer evicts its buffers.
        # Buffers are refcounted across FDbs — StreamingFDb snapshots
        # share flushed Shards (hence arrays), so an id is only evicted
        # once every FDb that primed it is gone.
        self._primed_fdbs: weakref.WeakSet = weakref.WeakSet()
        self._primed_refs: Dict[int, int] = {}
        # per-FDb primed key sets (shared with that FDb's finalizer, so
        # eager retirement can shrink them) + the latest primed snapshot
        # per source name for streaming generation turnover
        self._primed_keysets: "weakref.WeakKeyDictionary" = \
            weakref.WeakKeyDictionary()
        self._latest_primed: Dict[str, "weakref.ref"] = {}
        # id(track lat values) → (lat values pin, pts [4, P], rows [P]):
        # the packed integer form the refine kernel consumes, computed
        # once per shard at prime time (see exec.refine.pack_track_points)
        self._track_packs: Dict[int, Tuple[np.ndarray, np.ndarray,
                                           np.ndarray]] = {}
        # The query server opens/closes FDbs from many threads at once:
        # priming, finalizer release, and the pack cache share one lock so
        # refcounts stay consistent and eviction can never interleave with
        # a prime of the same buffers.  Reentrant because prime_fdb calls
        # _track_pack while holding it.
        self._prime_lock = threading.RLock()

    def _impl(self) -> str:
        return self.impl or self._ops.default_impl()

    def intersect_bitmaps(self, full, bitmaps):
        if not bitmaps:
            return full
        stack = bitmap_stack([full, *bitmaps])
        bm, _count = self._ops.bitmap_intersect(self._jnp.asarray(stack),
                                                impl=self._impl())
        return np.asarray(bm, dtype=np.uint32)

    def select_ids(self, bitmap, n):
        return self.compact_mask(mask_from_bitmap(bitmap, n))

    def compact_mask(self, mask):
        mask = np.asarray(mask, dtype=bool)
        idx, count = self._ops.compact(self._jnp.asarray(mask),
                                       impl=self._impl())
        return np.asarray(idx[: int(count)], dtype=np.int64)

    def _segment_dispatch(self, codes32: np.ndarray, values: np.ndarray,
                          num_groups: int):
        """One segment_agg launch → host (count int64, sum f64, sumsq f64)."""
        impl = self._impl()
        if impl == "reference":
            # float64 + row-order accumulation: bit-equal to the numpy
            # oracle, and the same segment math the kernel implements.
            with self._jax.experimental.enable_x64():
                cnt, s, s2 = self._ops.segment_agg(
                    self._jnp.asarray(codes32),
                    self._jnp.asarray(np.asarray(values, dtype=np.float64)),
                    num_groups, impl=impl)
                cnt, s, s2 = (np.asarray(cnt), np.asarray(s, np.float64),
                              np.asarray(s2, np.float64))
        else:
            cnt, s, s2 = self._ops.segment_agg(
                self._jnp.asarray(codes32),
                self._jnp.asarray(np.asarray(values, dtype=np.float32)),
                num_groups, impl=impl)
            cnt, s, s2 = (np.asarray(cnt), np.asarray(s, np.float64),
                          np.asarray(s2, np.float64))
        return np.rint(cnt).astype(np.int64), s, s2

    def segment_aggregate(self, codes, values, num_groups):
        codes32 = np.ascontiguousarray(codes, dtype=np.int32)
        return self._segment_dispatch(codes32, values, num_groups)

    # ------------------------------------------------------------- batched
    def probe_shards(self, fulls, probes):
        """One ``bitmap_intersect_batched`` launch for the whole wave.

        Ragged per-shard word counts are zero-padded to the wave max —
        sound because row 0 of every stack is the shard's valid-doc mask,
        which is zero in the pad region.  Shards with fewer probes than
        the wave max are padded with copies of their valid-doc mask (an
        AND no-op).
        """
        fulls = list(fulls)
        probes = [list(ps) for ps in probes]
        n_shards = len(fulls)
        if n_shards == 0:
            return []
        w = max(f.size for f in fulls)
        if w == 0:                       # a wave of entirely empty shards
            return [f.copy() for f in fulls]
        k = 1 + max(len(ps) for ps in probes)
        stack = np.zeros((n_shards, k, w), dtype=np.uint32)
        for i, (f, ps) in enumerate(zip(fulls, probes)):
            stack[i, 0, :f.size] = f
            for j, b in enumerate(ps):
                stack[i, j + 1, :b.size] = b
            for j in range(len(ps) + 1, k):
                stack[i, j, :f.size] = f
        bms, _counts = self._ops.bitmap_intersect_batched(
            self._jnp.asarray(stack), impl=self._impl())
        bms = np.asarray(bms, dtype=np.uint32)
        return [bms[i, :fulls[i].size].copy() for i in range(n_shards)]

    def probe_shards_multi(self, fulls, probes_multi):
        """Q queries' wave probes in ONE ``bitmap_intersect_batched``
        launch: the query axis is folded into the stacked shard axis
        ([Q·S, K, W]) — the AND-reduce is row-independent, so per-query
        slices are byte-equal to the loop-over-queries oracle."""
        fulls = list(fulls)
        probes_multi = [[list(ps) for ps in probes]
                        for probes in probes_multi]
        n_q, n_shards = len(probes_multi), len(fulls)
        if n_q == 0:
            return []
        if n_shards == 0:
            return [[] for _ in range(n_q)]
        w = max(f.size for f in fulls)
        if w == 0:
            return [[f.copy() for f in fulls] for _ in range(n_q)]
        k = 1 + max(len(ps) for probes in probes_multi for ps in probes)
        stack = np.zeros((n_q * n_shards, k, w), dtype=np.uint32)
        for q, probes in enumerate(probes_multi):
            for i, (f, ps) in enumerate(zip(fulls, probes)):
                row = q * n_shards + i
                stack[row, 0, :f.size] = f
                for j, b in enumerate(ps):
                    stack[row, j + 1, :b.size] = b
                for j in range(len(ps) + 1, k):
                    stack[row, j, :f.size] = f
        bms, _counts = self._ops.bitmap_intersect_batched(
            self._jnp.asarray(stack), impl=self._impl())
        bms = np.asarray(bms, dtype=np.uint32)
        return [[bms[q * n_shards + i, :fulls[i].size].copy()
                 for i in range(n_shards)] for q in range(n_q)]

    def compact_masks(self, masks):
        """One ``compact_batched`` launch for the whole wave (False-pad)."""
        masks = [np.asarray(m, dtype=bool) for m in masks]
        n_shards = len(masks)
        if n_shards == 0:
            return []
        n = max(m.size for m in masks)
        if n == 0:
            return [np.zeros(0, dtype=np.int64) for _ in masks]
        stack = np.zeros((n_shards, n), dtype=bool)
        for i, m in enumerate(masks):
            stack[i, :m.size] = m
        idx, counts = self._ops.compact_batched(self._jnp.asarray(stack),
                                                impl=self._impl())
        idx = np.asarray(idx)
        counts = np.asarray(counts)
        return [idx[i, :int(counts[i])].astype(np.int64)
                for i in range(n_shards)]

    def segment_aggregate_batched(self, codes, values, num_groups):
        """One segment launch per wave: shard-local group codes are offset
        into a disjoint global code space, aggregated together, and split
        back per shard.  Groups stay disjoint and rows keep their order,
        so every per-group accumulation sums the same values in the same
        order as the loop-over-shards oracle — bit-equal results.
        """
        num_groups = [int(g) for g in num_groups]
        total_groups = sum(num_groups)
        if total_groups == 0 or not codes:
            return [(np.zeros(0, np.int64), np.zeros(0), np.zeros(0))
                    for _ in codes]
        offsets = np.concatenate([[0], np.cumsum(num_groups)])
        shifted = []
        for c, off in zip(codes, offsets[:-1]):
            c32 = np.ascontiguousarray(c, dtype=np.int32)
            shifted.append(np.where(c32 >= 0, c32 + np.int32(off),
                                    np.int32(-1)).astype(np.int32))
        codes_cat = np.concatenate(shifted) if shifted else \
            np.zeros(0, np.int32)
        vals_cat = np.concatenate([np.asarray(v) for v in values]) if values \
            else np.zeros(0)
        cnt, s, s2 = self._segment_dispatch(codes_cat, vals_cat,
                                            total_groups)
        out = []
        for g, off in zip(num_groups, offsets[:-1]):
            off = int(off)
            out.append((cnt[off:off + g], s[off:off + g], s2[off:off + g]))
        return out

    # ---------------------------------------------------- device residence
    def _release_primed(self, keys, retire: bool = False) -> None:
        """Drop an FDb's buffer refs; evict at zero refcount.  Runs as
        the per-FDb GC finalizer and, with ``retire=True``, as the eager
        snapshot-turnover path (evictions then count on
        ``device_cache.retired_buffers``)."""
        with self._prime_lock:
            gone = []
            for key in list(keys):
                n = self._primed_refs.get(key, 0) - 1
                if n <= 0:
                    self._primed_refs.pop(key, None)
                    gone.append(key)
                    self._track_packs.pop(key, None)
                else:
                    self._primed_refs[key] = n
            if gone:
                self.device_cache.drop(gone, retired=retire)

    def prime_fdb(self, db) -> int:
        """Put ``db``'s stable buffers on device once (idempotent per FDb):
        column values/row_splits, valid-doc bitmaps, spacetime postings.
        Returns the number of buffers *newly* uploaded by this call.

        Priming is **incremental across streaming generations**: the
        device cache keys buffers by host-array identity, and successive
        ``StreamingFDb`` snapshots share their sealed/delta ``Shard``
        objects — so priming generation N+1 uploads only the new delta
        (and memtable-tail) buffers; everything already resident is a
        dict hit, not a host→device copy.  Refcounts still track every
        shared buffer per FDb, so eviction waits for the *last* snapshot
        using a buffer to be collected.

        A finalizer releases the buffers when the FDb is collected; shared
        buffers (snapshots sharing Shards) survive until their last FDb.
        Thread-safe: concurrent primes/releases of the same FDb (the query
        server's many sessions) serialize on the prime lock, so refcounts
        balance and eviction never fires mid-prime."""
        with self._prime_lock:
            if db in self._primed_fdbs:
                return 0
            before = len(self.device_cache)
            primed: List[np.ndarray] = []
            for shard in db.shards:
                primed.append(shard.all_bitmap())
                for col in shard.batch.columns.values():
                    primed.append(col.values)
                    if col.row_splits is not None:
                        primed.append(col.row_splits)
                for (path, kind), idx in shard.indexes.items():
                    if kind == "spacetime":
                        primed.extend((idx.keys, idx.splits, idx.doc_ids,
                                       idx.t_min, idx.t_max))
                        # packed refine-kernel form of the ragged track —
                        # stable per shard, so pack once and keep resident
                        pts, rows = self._track_pack(shard.batch, path,
                                                     pin=True)
                        if pts is not None:
                            primed.extend((pts, rows))
            keys = set()
            for arr in primed:
                self.device_cache.put(arr)
                keys.add(id(arr))
            for key in keys:
                self._primed_refs[key] = self._primed_refs.get(key, 0) + 1
            self._primed_fdbs.add(db)
            # the finalizer shares this (mutable) key set: eager
            # retirement below removes keys it already released, so the
            # finalizer can never double-decrement them
            self._primed_keysets[db] = keys
            weakref.finalize(db, self._release_primed, keys)
            uploaded = len(self.device_cache) - before
            # eager snapshot turnover: priming a newer snapshot of the
            # same source retires the replaced generation's *exclusive*
            # buffers (its memtable-tail shard — sealed/delta shards are
            # shared by identity and stay resident) right now, instead
            # of waiting for the old snapshot's GC finalizer
            prev_ref = self._latest_primed.get(db.name)
            prev = prev_ref() if prev_ref is not None else None
            self._latest_primed[db.name] = weakref.ref(db)
            if prev is not None and prev is not db:
                prev_keys = self._primed_keysets.get(prev)
                if prev_keys:
                    stale = prev_keys - keys
                    if stale:
                        prev_keys -= stale
                        self._release_primed(stale, retire=True)
            return uploaded

    # --------------------------------------------------------- track refine
    def _track_pack(self, batch, path: str, pin: bool = False):
        """(pts, rows) packed refine form for ``batch``'s track at
        ``path`` — cached per shard by the lat buffer's identity.

        Caching pins the source array, so entries are only inserted when
        their release is guaranteed: at ``prime_fdb`` time (``pin=True``)
        or when the buffer already belongs to a primed FDb — both paths
        are dropped by the per-FDb finalizer.  Packs for never-primed
        batches are computed per call instead of leaking forever."""
        lat_path = path + ".lat"
        if lat_path not in batch.columns:
            return None, None
        lat = batch[lat_path]
        hit = self._track_packs.get(id(lat.values))
        if hit is not None:
            return hit[1], hit[2]
        pts, rows = pack_track_points(lat.values, batch[path + ".lng"].values,
                                      batch[path + ".t"].values,
                                      lat.row_splits)
        with self._prime_lock:
            if pin or id(lat.values) in self._primed_refs:
                self._track_packs[id(lat.values)] = (lat.values, pts, rows)
        return pts, rows

    def _dev(self, arr: np.ndarray):
        """Device buffer for ``arr`` (resident when primed, else upload)."""
        dev = self.device_cache.get(arr)
        return dev if dev is not None else self._jnp.asarray(arr)

    def _order_ok(self, fh_hi, fh_lo, i: int, j: int):
        """Device-side strict first-hit compare for ordering edge (i, j):
        (hi, lo) uint32 word pairs, 64-bit lexicographic — True where the
        first hit of constraint i is strictly before constraint j's.
        ``fh_*`` index constraints on axis -2 (works for [C, D] and
        [S, C, D])."""
        a_hi, a_lo = fh_hi[..., i, :], fh_lo[..., i, :]
        b_hi, b_lo = fh_hi[..., j, :], fh_lo[..., j, :]
        return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))

    @staticmethod
    def _fh_table(fh_hi: np.ndarray, fh_lo: np.ndarray,
                  candidates: Optional[np.ndarray]) -> np.ndarray:
        """Kernel (hi, lo) word pair [C, n] → host uint64 table [n, C],
        masked to the sentinel outside ``candidates`` (byte parity with
        the restricted host oracle, which never evaluates those docs)."""
        table = ((fh_hi.astype(np.uint64) << np.uint64(32))
                 | fh_lo.astype(np.uint64)).T.copy()
        if candidates is not None:
            table[~np.asarray(candidates, dtype=bool), :] = FIRST_HIT_NONE
        return table

    @staticmethod
    def _an_tables(lh_hi: np.ndarray, lh_lo: np.ndarray, cnt: np.ndarray,
                   candidates: Optional[np.ndarray]
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Kernel last-hit word pair + count plane [C, n] → host uint64
        last-hit table [n, C] and int64 count table, masked to the no-hit
        identities outside ``candidates`` (byte parity with the restricted
        oracle, which never evaluates those docs)."""
        last = ((lh_hi.astype(np.uint64) << np.uint64(32))
                | lh_lo.astype(np.uint64)).T.copy()
        count = cnt.T.astype(np.int64)
        if candidates is not None:
            off = ~np.asarray(candidates, dtype=bool)
            last[off, :] = LAST_HIT_NONE
            count[off, :] = 0
        return last, count

    def refine_tracks(self, batch, path, constraints,
                      candidates=None, edges=(),
                      with_first_hits: bool = False,
                      min_counts=None, dwells=None,
                      with_analytics: bool = False):
        """One ``refine_tracks`` kernel launch over the full shard track
        (device-resident when primed), AND-combined with ``candidates`` on
        the host — byte-equal to the restricted numpy oracle because the
        per-doc verdict is independent of other docs.  Ordering ``edges``
        are a pure device-side compare over the first-hit table the same
        launch produces (no extra dispatch).  Count/dwell reductions (or
        an explicit ``with_analytics``) pull the full reduction tables
        from the same launch and recompute the verdict host-side from the
        count table (``exec.refine.reduction_verdict`` — the kernel's
        all-hit mask can't express vacuous k=0 constraints)."""
        constraints = list(constraints)
        edges = list(edges)
        if not constraints or len(constraints) > 30 or batch.n == 0:
            # >30 constraints would overflow the kernel's int32 bitset
            return super().refine_tracks(batch, path, constraints,
                                         candidates, edges=edges,
                                         with_first_hits=with_first_hits,
                                         min_counts=min_counts,
                                         dwells=dwells,
                                         with_analytics=with_analytics)
        pts, rows = self._track_pack(batch, path)
        if pts is None:
            return super().refine_tracks(batch, path, constraints,
                                         candidates, edges=edges,
                                         with_first_hits=with_first_hits,
                                         min_counts=min_counts,
                                         dwells=dwells,
                                         with_analytics=with_analytics)
        cov = pack_constraints(constraints)
        if with_analytics or _has_red(min_counts, dwells):
            _, fh_hi, fh_lo, lh_hi, lh_lo, cnt = self._ops.refine_tracks(
                self._dev(pts), self._dev(rows), self._jnp.asarray(cov),
                batch.n, impl=self._impl(), with_analytics=True)
            first = self._fh_table(np.asarray(fh_hi), np.asarray(fh_lo),
                                   candidates)
            last, count = self._an_tables(np.asarray(lh_hi),
                                          np.asarray(lh_lo),
                                          np.asarray(cnt), candidates)
            mask = reduction_verdict(first, last, count, edges,
                                     min_counts, dwells)
            if candidates is not None:
                mask &= np.asarray(candidates, dtype=bool)
            if with_analytics:
                return mask, first, last, count
            return (mask, first) if with_first_hits else mask
        need_fh = bool(edges) or with_first_hits
        if need_fh:
            mask_d, fh_hi, fh_lo = self._ops.refine_tracks(
                self._dev(pts), self._dev(rows), self._jnp.asarray(cov),
                batch.n, impl=self._impl(), with_first_hits=True)
            for i, j in edges:
                mask_d = mask_d & self._order_ok(fh_hi, fh_lo, i, j)
            mask = np.array(mask_d, dtype=bool)
        else:
            mask = np.array(self._ops.refine_tracks(
                self._dev(pts), self._dev(rows), self._jnp.asarray(cov),
                batch.n, impl=self._impl()), dtype=bool)
        if candidates is not None:
            mask &= np.asarray(candidates, dtype=bool)
        if with_first_hits:
            return mask, self._fh_table(np.asarray(fh_hi),
                                        np.asarray(fh_lo), candidates)
        return mask

    def refine_tracks_batched(self, batches, path, constraints,
                              candidates_list=None, edges=(),
                              with_first_hits: bool = False,
                              min_counts=None, dwells=None,
                              with_analytics: bool = False):
        """One ``refine_tracks_batched`` launch for the whole wave: the
        shards' packed point buffers are stacked (device-side when
        resident) and every shard shares the query's constraint table.
        Ragged point/doc counts are padded with never-matching rows.
        Ordering ``edges`` stay on device: the strict first-hit compare
        runs over the launch's stacked (hi, lo) tables before the masks
        come back to feed ``compact_masks``.  Count/dwell reductions (or
        ``with_analytics``) pull the stacked reduction tables from the
        same launch and recompute each shard's verdict host-side via
        ``exec.refine.reduction_verdict``."""
        batches = list(batches)
        constraints = list(constraints)
        edges = list(edges)
        if candidates_list is None:
            candidates_list = [None] * len(batches)
        need_an = with_analytics or _has_red(min_counts, dwells)
        if not batches:
            if with_analytics:
                return [], [], [], []
            return ([], []) if with_first_hits else []
        if not constraints or len(constraints) > 30:
            return super().refine_tracks_batched(batches, path, constraints,
                                                 candidates_list,
                                                 edges=edges,
                                                 with_first_hits=with_first_hits,
                                                 min_counts=min_counts,
                                                 dwells=dwells,
                                                 with_analytics=with_analytics)
        packs = [self._track_pack(b, path) for b in batches]
        if any(pts is None for pts, _ in packs):
            return super().refine_tracks_batched(batches, path, constraints,
                                                 candidates_list,
                                                 edges=edges,
                                                 with_first_hits=with_first_hits,
                                                 min_counts=min_counts,
                                                 dwells=dwells,
                                                 with_analytics=with_analytics)
        need_fh = bool(edges) or with_first_hits
        ns = [b.n for b in batches]
        n_max = max(ns)
        p_max = max(pts.shape[1] for pts, _ in packs)
        tables: List[np.ndarray] = []
        lasts: List[np.ndarray] = []
        counts: List[np.ndarray] = []
        if n_max == 0 or p_max == 0:
            n_c = len(constraints)
            tables = [np.full((n, n_c), FIRST_HIT_NONE,
                              dtype=np.uint64) for n in ns]
            lasts = [np.full((n, n_c), LAST_HIT_NONE, dtype=np.uint64)
                     for n in ns]
            counts = [np.zeros((n, n_c), dtype=np.int64) for n in ns]
            if need_an:
                # an all-empty-track wave is not automatically all-False:
                # vacuous (k <= 0) constraints still pass un-hit docs
                masks = [reduction_verdict(f, l, c, edges, min_counts,
                                           dwells)
                         for f, l, c in zip(tables, lasts, counts)]
            else:
                masks = [np.zeros(n, dtype=bool) for n in ns]
        elif need_an:
            jnp = self._jnp
            pts_pad, rows_pad = [], []
            for pts, rows in packs:
                p = pts.shape[1]
                dp, dr = self._dev(pts), self._dev(rows)
                if p < p_max:
                    dp = jnp.zeros((4, p_max), jnp.uint32).at[:, :p].set(dp)
                    dr = jnp.full((p_max,), -1, jnp.int32).at[:p].set(dr)
                pts_pad.append(dp)
                rows_pad.append(dr)
            _, fh_hi, fh_lo, lh_hi, lh_lo, cnt = \
                self._ops.refine_tracks_batched(
                    jnp.stack(pts_pad), jnp.stack(rows_pad),
                    jnp.asarray(pack_constraints(constraints)), n_max,
                    impl=self._impl(), with_analytics=True)
            hi_h, lo_h = np.asarray(fh_hi), np.asarray(fh_lo)
            lhi_h, llo_h = np.asarray(lh_hi), np.asarray(lh_lo)
            cnt_h = np.asarray(cnt)
            masks = []
            for i, (n, cand) in enumerate(zip(ns, candidates_list)):
                first = self._fh_table(hi_h[i, :, :n], lo_h[i, :, :n],
                                       cand)
                last, count = self._an_tables(lhi_h[i, :, :n],
                                              llo_h[i, :, :n],
                                              cnt_h[i, :, :n], cand)
                masks.append(reduction_verdict(first, last, count, edges,
                                               min_counts, dwells))
                tables.append(first)
                lasts.append(last)
                counts.append(count)
        else:
            jnp = self._jnp
            # pad each shard's resident buffers to the wave max, then one
            # stack — O(S·P_max) total copy (no per-shard full-stack copy)
            pts_pad, rows_pad = [], []
            for pts, rows in packs:
                p = pts.shape[1]
                dp, dr = self._dev(pts), self._dev(rows)
                if p < p_max:
                    dp = jnp.zeros((4, p_max), jnp.uint32).at[:, :p].set(dp)
                    dr = jnp.full((p_max,), -1, jnp.int32).at[:p].set(dr)
                pts_pad.append(dp)
                rows_pad.append(dr)
            pts_stack = jnp.stack(pts_pad)
            rows_stack = jnp.stack(rows_pad)
            cov = pack_constraints(constraints)
            if need_fh:
                out_d, fh_hi, fh_lo = self._ops.refine_tracks_batched(
                    pts_stack, rows_stack, self._jnp.asarray(cov), n_max,
                    impl=self._impl(), with_first_hits=True)
                for i, j in edges:
                    out_d = out_d & self._order_ok(fh_hi, fh_lo, i, j)
                out = np.asarray(out_d, dtype=bool)
            else:
                out = np.asarray(self._ops.refine_tracks_batched(
                    pts_stack, rows_stack, self._jnp.asarray(cov), n_max,
                    impl=self._impl()), dtype=bool)
            masks = [out[i, :n].copy() for i, n in enumerate(ns)]
            if with_first_hits:
                hi_h, lo_h = np.asarray(fh_hi), np.asarray(fh_lo)
                tables = [self._fh_table(hi_h[i, :, :n], lo_h[i, :, :n],
                                         cand)
                          for i, (n, cand) in enumerate(
                              zip(ns, candidates_list))]
        for m, cand in zip(masks, candidates_list):
            if cand is not None:
                m &= np.asarray(cand, dtype=bool)
        if with_analytics:
            return masks, tables, lasts, counts
        return (masks, tables) if with_first_hits else masks

    def refine_tracks_multi(self, batches, path, constraints_list,
                            candidates_lists=None, edges_list=None,
                            with_first_hits: bool = False,
                            min_counts_list=None, dwells_list=None):
        """Q coalesced queries' refine in ONE ``refine_tracks_multi``
        launch: the wave's track buffers are stacked once and shared, the
        per-query constraint tables ride a leading query axis (padded to
        common C/R — see ``exec.refine.pack_constraints_multi``).  Falls
        back to the loop-over-queries oracle when any query has 0/>30
        constraints or a shard lacks a packed track."""
        batches = list(batches)
        constraints_list = [list(c) for c in constraints_list]
        n_q = len(constraints_list)
        if candidates_lists is None:
            candidates_lists = [None] * n_q
        if edges_list is None:
            edges_list = [()] * n_q
        edges_list = [tuple(tuple(e) for e in es) for es in edges_list]
        if min_counts_list is None:
            min_counts_list = [None] * n_q
        if dwells_list is None:
            dwells_list = [None] * n_q
        need_an = any(_has_red(mc, dw)
                      for mc, dw in zip(min_counts_list, dwells_list))

        def fallback():
            return super(JaxBackend, self).refine_tracks_multi(
                batches, path, constraints_list, candidates_lists,
                edges_list, with_first_hits=with_first_hits,
                min_counts_list=min_counts_list, dwells_list=dwells_list)

        if n_q == 0 or not batches:
            return fallback()
        if any(not c or len(c) > 30 for c in constraints_list):
            return fallback()
        packs = [self._track_pack(b, path) for b in batches]
        if any(pts is None for pts, _ in packs):
            return fallback()
        ns = [b.n for b in batches]
        n_max = max(ns)
        p_max = max(pts.shape[1] for pts, _ in packs)
        if n_max == 0 or p_max == 0:
            return fallback()
        jnp = self._jnp
        pts_pad, rows_pad = [], []
        for pts, rows in packs:
            p = pts.shape[1]
            dp, dr = self._dev(pts), self._dev(rows)
            if p < p_max:
                dp = jnp.zeros((4, p_max), jnp.uint32).at[:, :p].set(dp)
                dr = jnp.full((p_max,), -1, jnp.int32).at[:p].set(dr)
            pts_pad.append(dp)
            rows_pad.append(dr)
        pts_stack = jnp.stack(pts_pad)
        rows_stack = jnp.stack(rows_pad)
        cov = pack_constraints_multi(constraints_list)
        if need_an:
            # one analytics launch; every query's verdict is recomputed
            # host-side from its slice of the reduction tables (pad
            # constraints sliced off — vacuous k=0 stays vacuous)
            _, fh_hi, fh_lo, lh_hi, lh_lo, cnt = \
                self._ops.refine_tracks_multi(
                    pts_stack, rows_stack, jnp.asarray(cov), n_max,
                    impl=self._impl(), with_analytics=True)
            hi_h, lo_h = np.asarray(fh_hi), np.asarray(fh_lo)
            lhi_h, llo_h = np.asarray(lh_hi), np.asarray(lh_lo)
            cnt_h = np.asarray(cnt)
            results = []
            for q in range(n_q):
                cands = candidates_lists[q]
                if cands is None:
                    cands = [None] * len(batches)
                c_q = len(constraints_list[q])
                mc, dw = min_counts_list[q], dwells_list[q]
                masks, tables = [], []
                for i, (n, cand) in enumerate(zip(ns, cands)):
                    first = self._fh_table(hi_h[q, i, :c_q, :n],
                                           lo_h[q, i, :c_q, :n], cand)
                    last, count = self._an_tables(lhi_h[q, i, :c_q, :n],
                                                  llo_h[q, i, :c_q, :n],
                                                  cnt_h[q, i, :c_q, :n],
                                                  cand)
                    m = reduction_verdict(first, last, count,
                                          edges_list[q], mc, dw)
                    if cand is not None:
                        m &= np.asarray(cand, dtype=bool)
                    masks.append(m)
                    tables.append(first)
                results.append((masks, tables) if with_first_hits
                               else masks)
            return results
        need_fh = with_first_hits or any(edges_list)
        if need_fh:
            out_d, fh_hi, fh_lo = self._ops.refine_tracks_multi(
                pts_stack, rows_stack, jnp.asarray(cov), n_max,
                impl=self._impl(), with_first_hits=True)
            masked = []
            for q, edges in enumerate(edges_list):
                m = out_d[q]
                for i, j in edges:
                    m = m & self._order_ok(fh_hi[q], fh_lo[q], i, j)
                masked.append(m)
            out = np.asarray(jnp.stack(masked), dtype=bool)
        else:
            out = np.asarray(self._ops.refine_tracks_multi(
                pts_stack, rows_stack, jnp.asarray(cov), n_max,
                impl=self._impl()), dtype=bool)
        if with_first_hits:
            hi_h, lo_h = np.asarray(fh_hi), np.asarray(fh_lo)
        results = []
        for q in range(n_q):
            cands = candidates_lists[q]
            if cands is None:
                cands = [None] * len(batches)
            masks = [out[q, i, :n].copy() for i, n in enumerate(ns)]
            for m, cand in zip(masks, cands):
                if cand is not None:
                    m &= np.asarray(cand, dtype=bool)
            if with_first_hits:
                # only the query's real constraints (pad rows sliced off)
                c_q = len(constraints_list[q])
                tables = [self._fh_table(hi_h[q, i, :c_q, :n],
                                         lo_h[q, i, :c_q, :n], cand)
                          for i, (n, cand) in enumerate(zip(ns, cands))]
                results.append((masks, tables))
            else:
                results.append(masks)
        return results

    def gather_columns(self, batch, paths, ids):
        """Selective read from device-resident buffers when primed: dense
        columns gather directly; repeated columns run the device-side
        ragged gather (CSR spans-concatenate over the resident value
        buffer, new row_splits built host-side from the shard's splits).
        Unprimed columns fall back to the host gather — identical values
        either way."""
        from ..fdb.columnar import Column, ColumnBatch
        sub = batch.select_paths(list(paths))
        ids = np.asarray(ids, dtype=np.int64)
        cols = {}
        dev_ids = None
        for p, c in sub.columns.items():
            dev = self.device_cache.get(c.values)
            if dev is None:
                cols[p] = c.gather(ids)
                continue
            with self._jax.experimental.enable_x64():
                if c.row_splits is None:
                    if dev_ids is None:
                        dev_ids = self._jnp.asarray(ids)
                    vals = np.asarray(dev[dev_ids])
                    cols[p] = Column(vals, None, c.vocab)
                    continue
                # device-side ragged gather: only the per-doc spans (one
                # entry per selected doc) go host→device; the O(points)
                # spans-concatenate index build and value gather run on
                # device against the resident CSR value buffer
                starts = c.row_splits[ids]
                ends = c.row_splits[ids + 1]
                new_splits = np.zeros(ids.size + 1, dtype=np.int64)
                np.cumsum(ends - starts, out=new_splits[1:])
                total = int(new_splits[-1])
                if total == 0:
                    vals = c.values[:0].copy()
                else:
                    jnp = self._jnp
                    splits_d = jnp.asarray(new_splits)
                    pos = jnp.arange(total, dtype=jnp.int64)
                    row = jnp.searchsorted(splits_d, pos,
                                           side="right") - 1
                    flat = jnp.asarray(starts)[row] + pos - splits_d[row]
                    vals = np.asarray(dev[flat])
                cols[p] = Column(vals, new_splits, c.vocab)
        return ColumnBatch(sub.schema, cols, ids.size)

    # ----------------------------------------------------- fused wave path
    def postings_bitmap(self, ids, t_min, t_max, t0, t1, n_docs):
        """Postings OR + span prune as one device pass over the resident
        ``t_min``/``t_max`` buffers (see ``kernels.fused``)."""
        with self._jax.experimental.enable_x64():
            tmin_d, tmax_d = self._dev(t_min), self._dev(t_max)
        bm = self._ops.postings_bitmap(np.asarray(ids, dtype=np.int64),
                                       tmin_d, tmax_d, float(t0), float(t1),
                                       n_docs, impl=self._impl())
        return np.asarray(bm, dtype=np.uint32)

    def segment_hll(self, codes, reg_idx, ranks, num_groups: int,
                    num_regs: int) -> np.ndarray:
        """One ``segment_hll`` launch: the (group, register) pair folds
        into a composite segment id and the rank plane max-reduces on
        device (``jax.ops.segment_max`` — exact uint8 integer max, so the
        result is byte-equal to the host scatter oracle)."""
        codes = np.asarray(codes, dtype=np.int64)
        reg_idx = np.asarray(reg_idx, dtype=np.int64)
        composite = np.where(codes >= 0, codes * num_regs + reg_idx, -1)
        out = self._ops.segment_hll(
            self._jnp.asarray(composite),
            self._jnp.asarray(np.asarray(ranks, dtype=np.uint8)[:, None]),
            num_groups * num_regs, impl=self._impl())
        return np.asarray(out)[:, 0].reshape(num_groups, num_regs)

    def _refine_stack(self, shards, packs, path: str):
        """Wave-stacked (pts [S, 4, P], rows [S, P]) device buffers for
        the fused refine stage, keyed in the DeviceCache per wave
        partition — resident per-shard packs are stacked once per
        partition instead of re-stacked every query.  Only cached when
        every source buffer is primed (the per-FDb finalizer then owns
        eviction); padding matches ``refine_tracks_batched``."""
        jnp = self._jnp
        p_max = max(p.shape[1] for p, _ in packs)
        src = tuple(id(sh.batch[path + ".lat"].values) for sh in shards)
        keyed_ok = all(k in self._primed_refs for k in src)
        key = ("refine_stack",) + src
        if keyed_ok:
            hit = self.device_cache.get_keyed(key)
            if hit is not None:
                return hit
        pts_pad, rows_pad = [], []
        for pts, rows in packs:
            p = pts.shape[1]
            dp, dr = self._dev(pts), self._dev(rows)
            if p < p_max:
                dp = jnp.zeros((4, p_max), jnp.uint32).at[:, :p].set(dp)
                dr = jnp.full((p_max,), -1, jnp.int32).at[:p].set(dr)
            pts_pad.append(dp)
            rows_pad.append(dr)
        out = (jnp.stack(pts_pad), jnp.stack(rows_pad))
        if keyed_ok:
            self.device_cache.put_keyed(key, out)
        return out

    def _agg_stacks(self, shards, agg, impl: str, n_max: int):
        """Offset-coded group-code stack [S, n_max] (−1 pad) plus one
        value stack per aggregated column for the fused segment stage,
        keyed in the DeviceCache per wave partition.  Value stacks are
        float64 under ``reference`` (bit-parity accumulation) and float32
        otherwise — the same cast ``_segment_dispatch`` applies."""
        jnp = self._jnp
        facts = [agg.factorize(sh, backend=self) for sh in shards]
        offsets = np.concatenate(
            [[0], np.cumsum([g for _, _, g in facts])]).astype(np.int64)
        total = int(offsets[-1])
        if total == 0:
            return facts, offsets, None, (), 0
        src = tuple(id(sh.batch[agg.key_path].values) for sh in shards)
        keyed_ok = all(k in self._primed_refs for k in src)
        ckey = ("agg_codes", n_max) + src
        codes_dev = self.device_cache.get_keyed(ckey) if keyed_ok else None
        if codes_dev is None:
            codes = np.full((len(shards), n_max), -1, dtype=np.int32)
            for i, (sh, (_, c, g)) in enumerate(zip(shards, facts)):
                if g:
                    codes[i, :sh.n] = c + np.int32(offsets[i])
            codes_dev = jnp.asarray(codes)
            if keyed_ok:
                self.device_cache.put_keyed(ckey, codes_dev)
        ftag = "f64" if impl == "reference" else "f32"
        dt = np.float64 if impl == "reference" else np.float32
        vals_dev = []
        for vp in (agg.value_paths or [None]):
            if vp is None:
                # count-only plan: a zeros stack so the segment stage
                # still returns per-group row counts
                with self._jax.experimental.enable_x64():
                    vals_dev.append(jnp.zeros((len(shards), n_max), dt))
                continue
            vsrc = tuple(id(sh.batch[vp].values) for sh in shards)
            vok = keyed_ok and all(k in self._primed_refs for k in vsrc)
            vkey = ("agg_vals", ftag, n_max) + vsrc
            dv = self.device_cache.get_keyed(vkey) if vok else None
            if dv is None:
                stack = np.zeros((len(shards), n_max), dtype=dt)
                for i, sh in enumerate(shards):
                    if sh.n:
                        stack[i, :sh.n] = np.asarray(sh.batch[vp].values,
                                                     dt)
                with self._jax.experimental.enable_x64():
                    dv = jnp.asarray(stack)
                if vok:
                    self.device_cache.put_keyed(vkey, dv)
            vals_dev.append(dv)
        return facts, offsets, codes_dev, tuple(vals_dev), total

    def run_wave_fused(self, shards, probes, refine=None, agg=None,
                       prefetch_shards=None, profile=None):
        """One fused dispatch for the whole wave (``kernels.fused``), or
        ``None`` to decline to the per-primitive path: a refine spec with
        zero or >30 constraints, a shard without a packed track, or a
        wave whose tracks are all empty (the legacy path's host shortcut
        already covers that case).  ``prefetch_shards`` — the next wave's
        shards — are staged *before* this wave's outputs sync back to the
        host, overlapping upload with compute."""
        import time as _time
        shards = list(shards)
        probes = [list(ps) for ps in probes]
        if not shards:
            return [], [], ([] if agg is not None else None)
        packs = None
        edges: Tuple = ()
        mcs: Tuple = ()
        dws: Tuple = ()
        if refine is not None:
            cons = list(refine.constraints)
            edges = tuple(tuple(e) for e in refine.edges)
            mcs = tuple(int(k) for k in
                        (getattr(refine, "min_counts", None) or ()))
            dws = tuple(None if d is None else float(d) for d in
                        (getattr(refine, "dwells", None) or ()))
            if not _has_red(mcs, dws):
                # default reductions: keep the legacy jit-cache key
                mcs, dws = (), ()
            if not cons or len(cons) > 30:
                return None
            packs = [self._track_pack(sh.batch, refine.path)
                     for sh in shards]
            if any(p is None for p, _ in packs):
                return None
        ns = [sh.n for sh in shards]
        n_max = max(ns)
        fulls = [sh.all_bitmap() for sh in shards]
        w = max(f.size for f in fulls)
        if n_max == 0 or w == 0:
            # all-empty wave: nothing to compute, but it still counts one
            # fused dispatch so the ⌈shards/wave⌉ total-launch contract
            # stays exact
            self._ops.record_launch("run_wave_fused")
            if prefetch_shards:
                self.prefetch_wave(prefetch_shards, refine, agg)
            seg = ([(np.zeros(0, dtype=np.int64), []) for _ in shards]
                   if agg is not None else None)
            return ([0] * len(shards),
                    [np.zeros(0, dtype=np.int64) for _ in shards], seg)
        if refine is not None and max(p.shape[1] for p, _ in packs) == 0:
            return None
        impl = self._impl()
        if profile is None:     # explicit config wins over the env knob
            profile = os.environ.get("REPRO_EXEC_PROFILE") == "1"
        t_up = _time.perf_counter()
        k = 1 + max((len(ps) for ps in probes), default=0)
        stack = np.zeros((len(shards), k, w), dtype=np.uint32)
        for i, (f, ps) in enumerate(zip(fulls, probes)):
            stack[i, 0, :f.size] = f
            for j, b in enumerate(ps):
                stack[i, j + 1, :b.size] = b
            for j in range(len(ps) + 1, k):
                stack[i, j, :f.size] = f
        probe_dev = self._jnp.asarray(stack)
        ns_dev = self._jnp.asarray(np.asarray(ns, dtype=np.int32))
        pts_stack = rows_stack = cov_dev = None
        if refine is not None:
            pts_stack, rows_stack = self._refine_stack(shards, packs,
                                                       refine.path)
            cov_dev = self._jnp.asarray(pack_constraints(cons))
        codes_dev, vals_dev, total = None, (), 0
        facts, offsets = [], None
        if agg is not None:
            facts, offsets, codes_dev, vals_dev, total = \
                self._agg_stacks(shards, agg, impl, n_max)
        if profile:
            self._jax.block_until_ready(probe_dev)
            self._fused.record_stage(
                "upload", (_time.perf_counter() - t_up) * 1e3)
        minmax = tuple(getattr(agg, "minmax", ()) or ()) \
            if agg is not None else ()
        cand, sel_idx, sel_counts, segs = self._ops.run_wave_fused(
            probe_dev, ns_dev, pts_stack, rows_stack, cov_dev, codes_dev,
            vals_dev, num_docs=n_max, edges=edges, min_counts=mcs,
            dwells=dws, total_groups=total, impl=impl, profile=profile,
            minmax=minmax)
        # stage wave k+1's buffers before wave k's outputs sync to host
        if prefetch_shards:
            self.prefetch_wave(prefetch_shards, refine, agg)
        idx_h = np.asarray(sel_idx)
        counts_h = np.asarray(sel_counts)
        n_cands = [int(c) for c in np.asarray(cand)]
        ids_list = [idx_h[i, :int(counts_h[i])].astype(np.int64)
                    for i in range(len(shards))]
        seg = None
        if agg is not None:
            # slots are (count, sum, sumsq) triples, or 5-tuples with the
            # per-group min/max planes appended for flagged value slots
            slot_host = []
            for st in (segs or []):
                slot = (np.rint(np.asarray(st[0])).astype(np.int64),
                        np.asarray(st[1], dtype=np.float64),
                        np.asarray(st[2], dtype=np.float64))
                if len(st) == 5:
                    slot = (*slot, np.asarray(st[3], dtype=np.float64),
                            np.asarray(st[4], dtype=np.float64))
                slot_host.append(slot)
            seg = []
            for i, (uniq, _c, g) in enumerate(facts):
                off = int(offsets[i])
                # g == 0 → (uniq, []) exactly like the base-class oracle
                seg.append((uniq,
                            [tuple(a[off:off + g] for a in slot)
                             for slot in slot_host] if g else []))
        return n_cands, ids_list, seg

    def run_wave_fused_multi(self, shards, probes_multi, refines,
                             prefetch_shards=None):
        """Q coalesced selection queries through one wave in ONE
        ``run_wave_fused_multi`` dispatch: per-query probe stacks ride a
        leading query axis folded into the stacked probe/compact kernels,
        the per-query constraint tables a leading axis on the multi refine
        kernel, and the wave's track buffers are shared.  Declines
        (``None``) on the same conditions as the single-query fused path —
        the server then falls back to per-query execution."""
        shards = list(shards)
        probes_multi = [[list(ps) for ps in probes]
                        for probes in probes_multi]
        n_q = len(probes_multi)
        if n_q == 0:
            return []
        if not shards:
            return [([], []) for _ in range(n_q)]
        refines = list(refines)
        has_refine = any(r is not None for r in refines)
        path = None
        packs = None
        mcs_multi: Tuple = ()
        dws_multi: Tuple = ()
        if has_refine:
            if not all(r is not None for r in refines):
                return None              # mixed refine/no-refine group
            if len({r.path for r in refines}) != 1:
                return None
            path = refines[0].path
            cons_list = [list(r.constraints) for r in refines]
            if any(not c or len(c) > 30 for c in cons_list):
                return None
            mcs_multi = tuple(
                tuple(int(k) for k in
                      (getattr(r, "min_counts", None) or ()))
                for r in refines)
            dws_multi = tuple(
                tuple(None if d is None else float(d) for d in
                      (getattr(r, "dwells", None) or ()))
                for r in refines)
            if not any(_has_red(mc, dw)
                       for mc, dw in zip(mcs_multi, dws_multi)):
                # default reductions: keep the legacy jit-cache key
                mcs_multi = tuple(() for _ in refines)
                dws_multi = tuple(() for _ in refines)
            for mc, dw in zip(mcs_multi, dws_multi):
                if mc and all(int(k) <= 0 for k in mc) \
                        and not any(d is not None for d in dw):
                    # an all-vacuous query passes docs with zero points;
                    # the multi kernel's always-hit pad constraints can't
                    # express that — decline to the per-query path
                    return None
            packs = [self._track_pack(sh.batch, path) for sh in shards]
            if any(p is None for p, _ in packs):
                return None
        ns = [sh.n for sh in shards]
        n_max = max(ns)
        fulls = [sh.all_bitmap() for sh in shards]
        w = max(f.size for f in fulls)
        if n_max == 0 or w == 0:
            # all-empty wave: still one fused dispatch so the coalesced
            # ⌈shards/wave⌉ total-launch contract stays exact
            self._ops.record_launch("run_wave_fused_multi")
            if prefetch_shards:
                self.prefetch_wave(prefetch_shards,
                                   refines[0] if has_refine else None)
            return [([0] * len(shards),
                     [np.zeros(0, dtype=np.int64) for _ in shards])
                    for _ in range(n_q)]
        if has_refine and max(p.shape[1] for p, _ in packs) == 0:
            return None
        k = 1 + max((len(ps) for probes in probes_multi for ps in probes),
                    default=0)
        stack = np.zeros((n_q, len(shards), k, w), dtype=np.uint32)
        for q, probes in enumerate(probes_multi):
            for i, (f, ps) in enumerate(zip(fulls, probes)):
                stack[q, i, 0, :f.size] = f
                for j, b in enumerate(ps):
                    stack[q, i, j + 1, :b.size] = b
                for j in range(len(ps) + 1, k):
                    stack[q, i, j, :f.size] = f
        probe_dev = self._jnp.asarray(stack)
        ns_dev = self._jnp.asarray(np.asarray(ns, dtype=np.int32))
        pts_stack = rows_stack = cov_dev = None
        edges_multi = tuple(() for _ in range(n_q))
        if has_refine:
            pts_stack, rows_stack = self._refine_stack(shards, packs, path)
            cov_dev = self._jnp.asarray(pack_constraints_multi(cons_list))
            edges_multi = tuple(tuple(tuple(e) for e in r.edges)
                                for r in refines)
        cand, sel_idx, sel_counts = self._ops.run_wave_fused_multi(
            probe_dev, ns_dev, pts_stack, rows_stack, cov_dev,
            num_docs=n_max, edges_multi=edges_multi,
            min_counts_multi=mcs_multi, dwells_multi=dws_multi,
            impl=self._impl())
        if prefetch_shards:
            self.prefetch_wave(prefetch_shards,
                               refines[0] if has_refine else None)
        cand_h = np.asarray(cand)
        idx_h = np.asarray(sel_idx)
        counts_h = np.asarray(sel_counts)
        out = []
        for q in range(n_q):
            n_cands = [int(c) for c in cand_h[q]]
            ids_list = [idx_h[q, i, :int(counts_h[q, i])].astype(np.int64)
                        for i in range(len(shards))]
            out.append((n_cands, ids_list))
        return out

    def prefetch_wave(self, shards, refine=None, agg=None) -> None:
        """Double-buffered async prefetch: build (or re-find) the next
        wave's keyed stacked buffers — refine point stacks, offset group
        codes, value stacks — so its fused dispatch starts from resident
        device memory.  Device puts are non-blocking; nothing here syncs."""
        shards = list(shards)
        if not shards:
            return
        if self.trace_events is not None:
            self.trace_events.append(("prefetch", len(shards)))
        n_max = max(sh.n for sh in shards)
        if n_max == 0:
            return
        if refine is not None:
            cons = list(refine.constraints)
            if cons and len(cons) <= 30:
                packs = [self._track_pack(sh.batch, refine.path)
                         for sh in shards]
                if all(p is not None for p, _ in packs) and \
                        max(p.shape[1] for p, _ in packs) > 0:
                    self._refine_stack(shards, packs, refine.path)
        if agg is not None:
            self._agg_stacks(shards, agg, self._impl(), n_max)

    # ---------------------------------------------------- partition layer
    def partition_context(self, part: int, num_parts: int):
        """Run one partition's dispatches device-local: partition p of P
        pins its waves to exec-mesh device p mod D.  On a one-device host
        (CPU CI's emulated mesh) there is nothing to pin — the no-op
        keeps emulated P>1 runs byte-identical by construction."""
        if num_parts <= 1:
            return contextlib.nullcontext()
        devs = self._jax.devices()
        if len(devs) <= 1:
            return contextlib.nullcontext()
        return self._jax.default_device(devs[part % len(devs)])

    def merge_partials(self, states, minmax=(), parts=None):
        """One-launch device combine of the per-shard segment states:
        align every state to the sorted union key space host-side, stack
        ``[S, K, G]`` float64 planes (identity fill: 0 for
        count/sum/sum_sq, ±inf for min/max, False for presence), then
        dispatch ``ops.merge_partials`` under ``shard_map`` over the
        ``"part"`` axis of ``launch.mesh.make_exec_mesh``.  The in-order
        accumulation matches the numpy oracle bit for bit on the
        emulated (size-1 axis) mesh — see ``kernels/merge.py`` for the
        multi-device subtotal caveat — and the whole merge costs exactly
        one recorded launch per query."""
        from ..launch.mesh import make_exec_mesh

        states = [(np.asarray(k), list(slots)) for k, slots in states]
        live = [st for st in states if len(st[0]) and st[1]]
        mesh = make_exec_mesh(len(parts) if parts else 0)
        with self._jax.experimental.enable_x64():
            if not live:
                # nothing selected anywhere — still one combine launch,
                # keeping the launch contract exact (cf. all-empty waves)
                zero = np.zeros((1, 1, 0))
                self._ops.merge_partials(
                    zero.astype(np.int64), zero, zero, zero, zero,
                    np.zeros((1, 0), bool), mesh=mesh, impl=self.impl)
                return np.zeros(0, np.int64), []
            union = np.unique(np.concatenate([k for k, _ in live]))
            n_states = len(live)
            n_slots = max(len(slots) for _, slots in live)
            mm = tuple(minmax)
            mm = mm + (False,) * (n_slots - len(mm))
            g = union.size
            cnt = np.zeros((n_states, n_slots, g), np.int64)
            s = np.zeros((n_states, n_slots, g), np.float64)
            s2 = np.zeros((n_states, n_slots, g), np.float64)
            mn = np.full((n_states, n_slots, g), np.inf)
            mx = np.full((n_states, n_slots, g), -np.inf)
            msk = np.zeros((n_states, g), bool)
            for si, (keys, slots) in enumerate(live):
                idx = np.searchsorted(union, keys)
                for k, st in enumerate(slots):
                    cnt[si, k, idx] = np.asarray(st[0], np.int64)
                    s[si, k, idx] = np.asarray(st[1], np.float64)
                    s2[si, k, idx] = np.asarray(st[2], np.float64)
                    if len(st) >= 5:
                        mn[si, k, idx] = np.asarray(st[3], np.float64)
                        mx[si, k, idx] = np.asarray(st[4], np.float64)
                msk[si, idx] = np.asarray(slots[0][0]) > 0
            out = self._ops.merge_partials(cnt, s, s2, mn, mx, msk,
                                           mesh=mesh, impl=self.impl)
            o_cnt, o_s, o_s2, o_mn, o_mx = \
                [np.asarray(x) for x in out[:5]]
        merged = []
        for k in range(n_slots):
            slot = (o_cnt[k].astype(np.int64), o_s[k], o_s2[k])
            if mm[k]:
                slot = (*slot, o_mn[k], o_mx[k])
            merged.append(slot)
        return union, merged


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[[], ExecBackend]] = {}
_INSTANCES: Dict[str, ExecBackend] = {}


def register_backend(name: str, factory: Callable[[], ExecBackend]) -> None:
    """Register (or replace) a backend under ``name``."""
    _FACTORIES[name] = factory
    _INSTANCES.pop(name, None)


def backend_names() -> List[str]:
    return sorted(_FACTORIES)


register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)


def get_backend(spec: Optional[str] = None) -> ExecBackend:
    """Resolve a backend name (default: ``$REPRO_EXEC_BACKEND`` or numpy)."""
    name = spec or os.environ.get("REPRO_EXEC_BACKEND") or "numpy"
    if name not in _FACTORIES:
        raise ValueError(f"unknown exec backend {name!r}; "
                         f"registered: {backend_names()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _FACTORIES[name]()
    return _INSTANCES[name]


def as_backend(spec: Union[None, str, ExecBackend]) -> ExecBackend:
    """Accept None (env default), a registered name, or an instance."""
    if isinstance(spec, ExecBackend):
        return spec
    return get_backend(spec)
