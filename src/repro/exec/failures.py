"""Fault injection & straggler simulation.

The paper's two engines differ exactly in their failure story: Warp:AdHoc
is "best effort" (always-on cluster, retries pushed to the client) while
Warp:Flume checkpoints and auto-recovers.  To *test* both behaviours on one
machine we inject failures at the shard-task boundary — the same boundary a
real deployment loses when a machine restarts.

``FaultPlan`` is threaded through both engines; tests use it to assert
(a) AdHoc degrades to partial coverage and reports it, (b) Flume re-executes
lost work and returns exact results, (c) speculative execution beats
stragglers.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field as dc_field
from typing import Dict, Optional, Set, Tuple

__all__ = ["FaultPlan", "TaskFailure"]


class TaskFailure(RuntimeError):
    """Simulated machine failure while running a shard task."""


@dataclass
class FaultPlan:
    """Deterministic failure/straggler schedule.

    fail_once:   {(stage, shard)} — first attempt raises, retry succeeds
                 (transient machine restart).
    fail_always: {(stage, shard)} — every attempt raises (dead machine;
                 AdHoc must drop it, Flume must reroute to another worker —
                 which we model as succeeding after ``reroute_after``
                 attempts).
    straggle:    {(stage, shard): seconds} — sleep before computing.
    """

    fail_once: Set[Tuple[str, int]] = dc_field(default_factory=set)
    fail_always: Set[Tuple[str, int]] = dc_field(default_factory=set)
    straggle: Dict[Tuple[str, int], float] = dc_field(default_factory=dict)
    reroute_after: int = 3
    _attempts: Dict[Tuple[str, int], int] = dc_field(default_factory=dict)
    _lock: threading.Lock = dc_field(default_factory=threading.Lock)

    def check(self, stage: str, shard: int) -> None:
        """Called by workers at task start; raises to simulate failure."""
        key = (stage, shard)
        with self._lock:
            n = self._attempts.get(key, 0) + 1
            self._attempts[key] = n
        if key in self.straggle:
            time.sleep(self.straggle[key])
        if key in self.fail_once and n == 1:
            raise TaskFailure(f"injected transient failure: {key}")
        if key in self.fail_always and n < self.reroute_after:
            raise TaskFailure(f"injected persistent failure: {key}")

    def stages(self) -> Set[str]:
        """Stages named anywhere in the schedule — engines use this to
        tell a partition-axis-only plan (reroute before dispatch, waves
        stay batched) from per-shard faults (per-shard task scheduling)."""
        return ({s for s, _ in self.fail_once}
                | {s for s, _ in self.fail_always}
                | {s for s, _ in self.straggle})

    def attempts(self, stage: str, shard: int) -> int:
        with self._lock:
            return self._attempts.get((stage, shard), 0)


NO_FAULTS = FaultPlan()
