"""Consolidated execution configuration (:class:`ExecConfig`).

One documented entry point for the execution knobs that were previously
scattered across engine kwargs and ``REPRO_EXEC_*`` environment variables:

===========  =========================  =====================================
field        env fallback               meaning
===========  =========================  =====================================
backend      ``REPRO_EXEC_BACKEND``     execution backend ("numpy" | "jax" |
                                        an ``ExecBackend`` instance)
wave         ``REPRO_EXEC_WAVE``        shards per batched dispatch wave
partitions   ``REPRO_EXEC_PARTITIONS``  execution partitions per query
fused        ``REPRO_EXEC_FUSED``       single fused dispatch per wave
profile      ``REPRO_EXEC_PROFILE``     per-stage device sync + timing
===========  =========================  =====================================

Resolution order is **explicit field > environment variable > default** for
every knob: a field left ``None`` defers to the env var (and then the
built-in default), while a set field wins even when the env var disagrees —
``ExecConfig(fused=True)`` keeps fusion on under ``REPRO_EXEC_FUSED=0``.

``Session``, ``AdHocEngine``, ``FlumeEngine``, and ``QueryServer`` all
accept ``config=ExecConfig(...)``; the legacy per-field kwargs
(``backend=``, ``wave=``, ``partitions=``) remain as shims that fill the
corresponding unset config fields.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from typing import Any, Optional

__all__ = ["ExecConfig", "BACKEND_ENV", "WAVE_ENV", "PARTITIONS_ENV",
           "FUSED_ENV", "PROFILE_ENV"]

BACKEND_ENV = "REPRO_EXEC_BACKEND"
WAVE_ENV = "REPRO_EXEC_WAVE"
PARTITIONS_ENV = "REPRO_EXEC_PARTITIONS"
FUSED_ENV = "REPRO_EXEC_FUSED"
PROFILE_ENV = "REPRO_EXEC_PROFILE"


@dataclass(frozen=True)
class ExecConfig:
    backend: Any = None                  # name | ExecBackend | None
    wave: Optional[int] = None
    partitions: Optional[int] = None
    fused: Optional[bool] = None
    profile: Optional[bool] = None

    # -- construction -------------------------------------------------------
    def fill(self, **legacy) -> "ExecConfig":
        """Fields set here win; ``None`` fields take the legacy kwarg.

        This is the deprecation shim behind ``AdHocEngine(backend=...,
        wave=...)`` and friends — engine kwargs flow in through it so the
        config object stays the single source of truth.
        """
        updates = {k: v for k, v in legacy.items()
                   if v is not None and getattr(self, k) is None}
        return replace(self, **updates) if updates else self

    def replace(self, **kw) -> "ExecConfig":
        return replace(self, **kw)

    # -- resolution (explicit > env > default) ------------------------------
    def resolve_backend(self):
        from .backend import as_backend
        return as_backend(self.backend)

    def resolve_wave(self, backend=None) -> int:
        from .batched import wave_size
        return wave_size(self.wave, backend)

    def resolved_fused(self) -> bool:
        if self.fused is not None:
            return bool(self.fused)
        return os.environ.get(FUSED_ENV, "") != "0"

    def resolved_profile(self) -> bool:
        if self.profile is not None:
            return bool(self.profile)
        return os.environ.get(PROFILE_ENV) == "1"
