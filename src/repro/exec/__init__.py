"""Execution engines: Warp:AdHoc (interactive) and Warp:Flume (batch)."""
from .catalog import Catalog, StructureManager, ResourceManager, default_catalog
from .adhoc import AdHocEngine, QueryResult, default_engine
from .flume import FlumeEngine
from .failures import FaultPlan, TaskFailure

__all__ = ["Catalog", "StructureManager", "ResourceManager",
           "default_catalog", "AdHocEngine", "QueryResult", "default_engine",
           "FlumeEngine", "FaultPlan", "TaskFailure"]
