"""Execution engines: Warp:AdHoc (interactive) and Warp:Flume (batch).

Both engines run the same logical plan through a pluggable
:class:`ExecBackend` (``numpy`` host oracle, ``jax`` kernel dispatch) —
see :mod:`repro.exec.backend`.
"""
from .backend import (ExecBackend, JaxBackend, NumpyBackend, as_backend,
                      backend_names, get_backend, register_backend)
from .config import ExecConfig
from .batched import (DEFAULT_WAVE, partition_waves, run_wave_task,
                      wave_size)
from .catalog import Catalog, StructureManager, ResourceManager, default_catalog
from .adhoc import AdHocEngine, QueryResult, default_engine
from .device_cache import DeviceCache
from .flume import FlumeEngine
from .failures import FaultPlan, TaskFailure

__all__ = ["ExecConfig",
           "Catalog", "StructureManager", "ResourceManager",
           "default_catalog", "AdHocEngine", "QueryResult", "default_engine",
           "FlumeEngine", "FaultPlan", "TaskFailure",
           "ExecBackend", "NumpyBackend", "JaxBackend", "get_backend",
           "as_backend", "register_backend", "backend_names",
           "DEFAULT_WAVE", "wave_size", "partition_waves", "run_wave_task",
           "DeviceCache"]
