"""Stage processors (paper §4.3.6).

"Each stage of a WFL pipeline is internally implemented using *processors*,
such as find processor for find(), map processor for map(), and so on."
Both engines share these: Warp:AdHoc drives them interactively per shard;
Warp:Flume wraps each into a batch-stage function with checkpoints.

Servers evaluate record-parallel processors over their shards' column
batches and emit *partials*; the Mixer merges partials and runs the final
stage (``aggregate_consume``, global sort/limit/distinct).
"""
from __future__ import annotations

import numpy as np
from dataclasses import dataclass, field as dc_field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.exprs import (AggSpec, CollectedTable, EvalContext, Expr,
                          MakeProto, Val, eval_expr)
from ..core.flow import (AggregateOp, DistinctOp, FilterOp, FlattenOp,
                         JoinOp, LimitOp, MapOp, ModelApplyOp, Op, SortOp,
                         SubFlowOp)
from ..core.sketches import HyperLogLog, hash_values
from ..fdb.columnar import Column, ColumnBatch
from ..fdb.fdb import FDb
from ..fdb.index import ids_from_bitmap
from ..fdb.schema import BOOL, DOUBLE, INT, STRING, Schema

__all__ = ["val_to_column", "apply_map", "apply_filter", "apply_flatten",
           "apply_sort", "apply_limit", "apply_distinct", "apply_model",
           "apply_hash_join", "apply_sub_flow", "aggregate_produce",
           "merge_agg_partials", "aggregate_consume", "partition_by_hash",
           "AggPartial", "run_record_ops"]


# --------------------------------------------------------------------------
# Column/batch helpers
# --------------------------------------------------------------------------

def val_to_column(v: Val, n: int) -> Column:
    if v.table is not None:
        raise TypeError("record-valued expression must be reduced to leaf "
                        "fields before materialization (access .field)")
    vals = v.values
    if vals is None:
        raise TypeError("cannot materialize non-columnar value")
    vals = np.asarray(vals)
    if not v.is_repeated and vals.ndim == 0:
        vals = np.broadcast_to(vals, (n,)).copy()
    return Column(vals, v.splits, v.vocab)


def _dyn_schema(name: str, cols: Dict[str, Column]) -> Schema:
    spec = {}
    for p, c in cols.items():
        if c.vocab is not None:
            t = STRING
        elif c.values.dtype == np.bool_:
            t = BOOL
        elif c.values.dtype.kind in "iu":
            t = INT
        else:
            t = DOUBLE
        spec[p] = (t, c.is_repeated)
    return Schema.dynamic(name, spec)


# --------------------------------------------------------------------------
# Record-parallel processors
# --------------------------------------------------------------------------

def apply_map(batch: ColumnBatch, make: MakeProto) -> ColumnBatch:
    ctx = EvalContext(batch)
    cols = {name: val_to_column(eval_expr(e, ctx), batch.n)
            for name, e in make.fields}
    return ColumnBatch(_dyn_schema(batch.schema.name + "#map", cols), cols,
                       batch.n)


def apply_filter(batch: ColumnBatch, pred: Expr) -> ColumnBatch:
    v = eval_expr(pred, EvalContext(batch))
    if v.is_repeated:
        raise TypeError("filter() predicate must be singular "
                        "(reduce vectors with vsum/vmax/…)")
    mask = np.asarray(v.values, dtype=bool)
    if mask.ndim == 0:
        mask = np.broadcast_to(mask, (batch.n,))
    return batch.gather(np.nonzero(mask)[0])


def apply_flatten(batch: ColumnBatch, path: str) -> ColumnBatch:
    target = [p for p in batch.paths()
              if p == path or p.startswith(path + ".")]
    if not target:
        raise KeyError(f"flatten: no columns under {path!r}")
    sp = batch[target[0]].row_splits
    if sp is None:
        raise TypeError(f"flatten: {path!r} is not repeated")
    lens = np.diff(sp)
    n_new = int(sp[-1])
    cols: Dict[str, Column] = {}
    for p in batch.paths():
        c = batch[p]
        if p in target:
            cols[p] = Column(c.values, None, c.vocab)
        elif not c.is_repeated:
            cols[p] = Column(np.repeat(c.values, lens), None, c.vocab)
        else:
            if np.array_equal(c.row_splits, sp):
                cols[p] = Column(c.values, None, c.vocab)
            else:
                raise TypeError(
                    f"flatten: {p!r} is repeated with a different shape")
    return ColumnBatch(_dyn_schema(batch.schema.name + "#flat", cols), cols,
                       n_new)


def apply_sort(batch: ColumnBatch, op: SortOp) -> ColumnBatch:
    v = eval_expr(op.expr, EvalContext(batch))
    order = np.argsort(v.values, kind="stable")
    if op.descending:
        order = order[::-1]
    return batch.gather(order)


def apply_limit(batch: ColumnBatch, k: int) -> ColumnBatch:
    if batch.n <= k:
        return batch
    return batch.gather(np.arange(k))


def apply_distinct(batch: ColumnBatch, expr: Optional[Expr]) -> ColumnBatch:
    if expr is not None:
        v = eval_expr(expr, EvalContext(batch))
        keys = hash_values(v.values, v.vocab)
    else:
        acc = np.zeros(batch.n, dtype=np.uint64)
        for p in batch.paths():
            c = batch[p]
            if c.is_repeated:
                continue
            acc ^= hash_values(c.values, c.vocab) * np.uint64(
                0x9E3779B97F4A7C15)
        keys = acc
    _, first = np.unique(keys, return_index=True)
    return batch.gather(np.sort(first))


def apply_model(batch: ColumnBatch, op: ModelApplyOp) -> ColumnBatch:
    ctx = EvalContext(batch)
    cols = dict(batch.columns)
    ins = {name: np.asarray(eval_expr(e, ctx).values)
           for name, e in op.inputs}
    pred = np.asarray(op.model.apply_columns(ins))
    if pred.shape[0] != batch.n:
        raise ValueError("model output row count mismatch")
    cols[op.output] = Column(pred.astype(np.float64)
                             if pred.dtype.kind == "f" else pred)
    return ColumnBatch(_dyn_schema(batch.schema.name + "#model", cols), cols,
                       batch.n)


# --------------------------------------------------------------------------
# Joins
# --------------------------------------------------------------------------

def apply_hash_join(left: ColumnBatch, right: CollectedTable,
                    left_key: Expr, alias: str) -> ColumnBatch:
    """Inner hash join: left rows × matching right rows (paper Table 1)."""
    lk = eval_expr(left_key, EvalContext(left))
    if lk.is_repeated:
        raise TypeError("join key must be singular")
    rows = right.lookup_rows(np.asarray(lk.values), lk.vocab)
    keep = np.nonzero(rows >= 0)[0]
    lbatch = left.gather(keep)
    rrows = rows[keep]
    cols = dict(lbatch.columns)
    for p, c in right.batch.columns.items():
        cols[f"{alias}.{p}"] = c.gather(rrows)
    return ColumnBatch(_dyn_schema(left.schema.name + "#join", cols), cols,
                       lbatch.n)


def apply_sub_flow(left: ColumnBatch, right_db: FDb, key: Expr,
                   index_path: str, alias: str) -> ColumnBatch:
    """Index join (paper ``sub_flow``): probe the right FDb's tag index per
    key instead of scanning it — one output row per (left row, right doc)."""
    lk = eval_expr(key, EvalContext(left))
    if lk.is_repeated:
        raise TypeError("sub_flow key must be singular")
    keys = np.asarray(lk.values)
    uniq = np.unique(keys)
    left_rows: List[np.ndarray] = []
    right_parts: List[ColumnBatch] = []
    for shard in right_db.shards:
        idx = shard.index(index_path, "tag")
        if idx is None:
            raise RuntimeError(f"sub_flow: no tag index on "
                               f"{right_db.name}.{index_path}")
        for u in uniq:
            u_val = (lk.vocab[int(u)] if lk.vocab is not None else u)
            bm = idx.lookup(u_val)
            ids = ids_from_bitmap(bm, shard.n)
            if ids.size == 0:
                continue
            lrows = np.nonzero(keys == u)[0]
            # cross product left-rows × right-docs
            left_rows.append(np.repeat(lrows, ids.size))
            right_parts.append(shard.batch.gather(
                np.tile(ids, lrows.size)))
    if not left_rows:
        empty_ids = np.zeros(0, dtype=np.int64)
        lbatch = left.gather(empty_ids)
        cols = dict(lbatch.columns)
        for p in right_db.shards[0].batch.paths():
            c = right_db.shards[0].batch[p]
            cols[f"{alias}.{p}"] = c.gather(empty_ids)
        return ColumnBatch(_dyn_schema(left.schema.name + "#subflow", cols),
                           cols, 0)
    lrows_all = np.concatenate(left_rows)
    rbatch = ColumnBatch.concat(right_parts)
    lbatch = left.gather(lrows_all)
    cols = dict(lbatch.columns)
    for p, c in rbatch.columns.items():
        cols[f"{alias}.{p}"] = c
    return ColumnBatch(_dyn_schema(left.schema.name + "#subflow", cols),
                       cols, lbatch.n)


def partition_by_hash(batch: ColumnBatch, key: Expr, num_parts: int
                      ) -> List[ColumnBatch]:
    """Sharder-style hash repartition (paper §4.3.5: "Sharders perform
    intermediate shuffles and joins")."""
    v = eval_expr(key, EvalContext(batch))
    h = hash_values(v.values, v.vocab)
    part = (h % np.uint64(num_parts)).astype(np.int64)
    return [batch.gather(np.nonzero(part == i)[0]) for i in range(num_parts)]


# --------------------------------------------------------------------------
# Distributed aggregation (aggregate_produce / aggregate_consume, §4.3.4)
# --------------------------------------------------------------------------

@dataclass
class AggPartial:
    """Mergeable per-shard aggregation state."""
    groups: Dict[tuple, List[Any]] = dc_field(default_factory=dict)


def _key_tuples(batch: ColumnBatch, spec: AggSpec) -> List[tuple]:
    ctx = EvalContext(batch)
    key_arrays = []
    for _, e in spec.keys:
        v = eval_expr(e, ctx)
        if v.is_repeated:
            raise TypeError("group key must be singular")
        vals = np.asarray(v.values)
        if v.vocab is not None:
            vv = np.asarray(v.vocab, dtype=object)
            vals = vv[vals]
        key_arrays.append(vals)
    if not key_arrays:
        return [()] * batch.n
    return list(zip(*(a.tolist() for a in key_arrays)))


def aggregate_produce(batch: ColumnBatch, spec: AggSpec) -> AggPartial:
    ctx = EvalContext(batch)
    keys = _key_tuples(batch, spec)
    vals: List[Optional[np.ndarray]] = []
    vocabs: List[Optional[list]] = []
    for kind, name, e in spec.aggs:
        if e is None:
            vals.append(None)
            vocabs.append(None)
        else:
            v = eval_expr(e, ctx)
            if v.is_repeated:
                raise TypeError(f"aggregate input {name!r} must be singular")
            arr = np.asarray(v.values)
            if arr.ndim == 0:
                arr = np.broadcast_to(arr, (batch.n,))
            vals.append(arr)
            vocabs.append(v.vocab)

    # Group rows by key (host groupby; the device path uses the
    # segment_agg kernel over integer key codes — see kernels/segment_agg).
    order: Dict[tuple, List[int]] = {}
    for i, k in enumerate(keys):
        order.setdefault(k, []).append(i)

    part = AggPartial()
    for k, rows in order.items():
        rows_a = np.asarray(rows)
        accs: List[Any] = []
        for (kind, name, e), arr, voc in zip(spec.aggs, vals, vocabs):
            if kind == "count":
                accs.append(len(rows))
            elif kind == "sum":
                accs.append(float(arr[rows_a].sum()))
            elif kind == "avg":
                accs.append((float(arr[rows_a].sum()), len(rows)))
            elif kind == "std_dev":
                x = arr[rows_a].astype(np.float64)
                accs.append((float(x.sum()), float((x * x).sum()), len(rows)))
            elif kind == "min":
                accs.append(float(arr[rows_a].min()))
            elif kind == "max":
                accs.append(float(arr[rows_a].max()))
            elif kind == "approx_distinct":
                accs.append(HyperLogLog().add(arr[rows_a], voc))
            else:
                raise ValueError(kind)
        part.groups[k] = accs
    return part


def merge_agg_partials(parts: Sequence[AggPartial], spec: AggSpec
                       ) -> AggPartial:
    out = AggPartial()
    for p in parts:
        for k, accs in p.groups.items():
            if k not in out.groups:
                out.groups[k] = [a if not isinstance(a, HyperLogLog)
                                 else HyperLogLog(a.p, a.registers.copy())
                                 for a in accs]
                continue
            cur = out.groups[k]
            for i, (kind, name, e) in enumerate(spec.aggs):
                if kind == "count":
                    cur[i] += accs[i]
                elif kind == "sum":
                    cur[i] += accs[i]
                elif kind == "avg":
                    cur[i] = (cur[i][0] + accs[i][0], cur[i][1] + accs[i][1])
                elif kind == "std_dev":
                    cur[i] = (cur[i][0] + accs[i][0],
                              cur[i][1] + accs[i][1],
                              cur[i][2] + accs[i][2])
                elif kind == "min":
                    cur[i] = min(cur[i], accs[i])
                elif kind == "max":
                    cur[i] = max(cur[i], accs[i])
                elif kind == "approx_distinct":
                    cur[i].merge(accs[i])
    return out


def aggregate_consume(part: AggPartial, spec: AggSpec) -> ColumnBatch:
    """Finish accumulators → output batch (runs on the Mixer)."""
    keys = sorted(part.groups.keys(), key=lambda t: tuple(map(str, t)))
    n = len(keys)
    cols: Dict[str, Column] = {}
    for j, (name, _) in enumerate(spec.keys):
        col_vals = [k[j] for k in keys]
        if col_vals and isinstance(col_vals[0], str):
            cols[name] = Column.from_strings(col_vals)
        else:
            cols[name] = Column(np.asarray(col_vals))
    for i, (kind, name, e) in enumerate(spec.aggs):
        accs = [part.groups[k][i] for k in keys]
        if kind == "count":
            cols[name] = Column(np.asarray(accs, dtype=np.int64))
        elif kind in ("sum", "min", "max"):
            cols[name] = Column(np.asarray(accs, dtype=np.float64))
        elif kind == "avg":
            cols[name] = Column(np.asarray(
                [s / max(c, 1) for s, c in accs], dtype=np.float64))
        elif kind == "std_dev":
            out = []
            for s, s2, c in accs:
                m = s / max(c, 1)
                out.append(np.sqrt(max(s2 / max(c, 1) - m * m, 0.0)))
            cols[name] = Column(np.asarray(out, dtype=np.float64))
        elif kind == "approx_distinct":
            cols[name] = Column(np.asarray([h.estimate() for h in accs],
                                           dtype=np.float64))
    return ColumnBatch(_dyn_schema("agg", cols), cols, n)


# --------------------------------------------------------------------------
# Server-side record pipeline
# --------------------------------------------------------------------------

def run_record_ops(batch: ColumnBatch, ops: Sequence[Op], catalog,
                   collected_cache: Optional[Dict[int, CollectedTable]] = None
                   ) -> ColumnBatch:
    """Run record-parallel ops on one shard's (already index-selected) batch."""
    for op in ops:
        if isinstance(op, MapOp):
            batch = apply_map(batch, op.make)
        elif isinstance(op, FilterOp):
            batch = apply_filter(batch, op.pred)
        elif isinstance(op, FlattenOp):
            batch = apply_flatten(batch, op.path)
        elif isinstance(op, ModelApplyOp):
            batch = apply_model(batch, op)
        elif isinstance(op, JoinOp):
            table = collected_cache[id(op)] if collected_cache else None
            if table is None:
                raise RuntimeError("join table missing from broadcast cache")
            batch = apply_hash_join(batch, table, op.left_key, op.alias)
        elif isinstance(op, SubFlowOp):
            batch = apply_sub_flow(batch, catalog.get(op.right_fdb), op.key,
                                   op.index_path, op.alias)
        else:
            raise TypeError(f"non-record op on server: {type(op).__name__}")
    return batch
