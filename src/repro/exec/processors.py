"""Stage processors (paper §4.3.6).

"Each stage of a WFL pipeline is internally implemented using *processors*,
such as find processor for find(), map processor for map(), and so on."
Both engines share these: Warp:AdHoc drives them interactively per shard;
Warp:Flume wraps each into a batch-stage function with checkpoints.

Servers evaluate record-parallel processors over their shards' column
batches and emit *partials*; the Mixer merges partials and runs the final
stage (``aggregate_consume``, global sort/limit/distinct).
"""
from __future__ import annotations

import numpy as np
from dataclasses import dataclass, field as dc_field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.exprs import (AggSpec, CollectedTable, EvalContext, Expr,
                          MakeProto, Val, eval_expr)
from ..core.flow import (AggregateOp, DistinctOp, FilterOp, FlattenOp,
                         JoinOp, LimitOp, MapOp, ModelApplyOp, Op, SortOp,
                         SubFlowOp)
from ..core.sketches import HyperLogLog, hash_values, hll_register_rows
from ..fdb.columnar import Column, ColumnBatch
from ..fdb.fdb import FDb
from ..fdb.index import ids_from_bitmap
from ..fdb.schema import BOOL, DOUBLE, INT, STRING, Schema
from .backend import as_backend

__all__ = ["val_to_column", "apply_map", "apply_filter", "predicate_mask",
           "apply_flatten",
           "apply_sort", "apply_limit", "apply_distinct", "apply_model",
           "apply_hash_join", "apply_sub_flow", "aggregate_produce",
           "aggregate_produce_batched", "merge_agg_partials",
           "aggregate_consume", "partition_by_hash", "AggPartial",
           "run_record_ops"]


# --------------------------------------------------------------------------
# Column/batch helpers
# --------------------------------------------------------------------------

def val_to_column(v: Val, n: int) -> Column:
    if v.table is not None:
        raise TypeError("record-valued expression must be reduced to leaf "
                        "fields before materialization (access .field)")
    vals = v.values
    if vals is None:
        raise TypeError("cannot materialize non-columnar value")
    vals = np.asarray(vals)
    if not v.is_repeated and vals.ndim == 0:
        vals = np.broadcast_to(vals, (n,)).copy()
    return Column(vals, v.splits, v.vocab)


def _dyn_schema(name: str, cols: Dict[str, Column]) -> Schema:
    spec = {}
    for p, c in cols.items():
        if c.vocab is not None:
            t = STRING
        elif c.values.dtype == np.bool_:
            t = BOOL
        elif c.values.dtype.kind in "iu":
            t = INT
        else:
            t = DOUBLE
        spec[p] = (t, c.is_repeated)
    return Schema.dynamic(name, spec)


# --------------------------------------------------------------------------
# Record-parallel processors
# --------------------------------------------------------------------------

def apply_map(batch: ColumnBatch, make: MakeProto) -> ColumnBatch:
    ctx = EvalContext(batch)
    cols = {name: val_to_column(eval_expr(e, ctx), batch.n)
            for name, e in make.fields}
    return ColumnBatch(_dyn_schema(batch.schema.name + "#map", cols), cols,
                       batch.n)


def predicate_mask(batch: ColumnBatch, pred: Expr) -> np.ndarray:
    """Singular predicate → bool row mask [n] (shared by the per-shard
    filter and the wave runner's batched residual compact — one definition
    keeps the paths byte-identical).  Tesseract's exact pass no longer
    routes through here from ``find()``: the planner compiles it to the
    backend's ``refine_tracks`` op; this host evaluation of ``InSpaceTime``
    remains the ``filter()``-path fallback."""
    v = eval_expr(pred, EvalContext(batch))
    if v.is_repeated:
        raise TypeError("filter() predicate must be singular "
                        "(reduce vectors with vsum/vmax/…)")
    mask = np.asarray(v.values, dtype=bool)
    if mask.ndim == 0:
        mask = np.broadcast_to(mask, (batch.n,))
    return mask


def apply_filter(batch: ColumnBatch, pred: Expr,
                 backend=None) -> ColumnBatch:
    mask = predicate_mask(batch, pred)
    return batch.gather(as_backend(backend).compact_mask(mask))


def apply_flatten(batch: ColumnBatch, path: str) -> ColumnBatch:
    target = [p for p in batch.paths()
              if p == path or p.startswith(path + ".")]
    if not target:
        raise KeyError(f"flatten: no columns under {path!r}")
    sp = batch[target[0]].row_splits
    if sp is None:
        raise TypeError(f"flatten: {path!r} is not repeated")
    lens = np.diff(sp)
    n_new = int(sp[-1])
    cols: Dict[str, Column] = {}
    for p in batch.paths():
        c = batch[p]
        if p in target:
            cols[p] = Column(c.values, None, c.vocab)
        elif not c.is_repeated:
            cols[p] = Column(np.repeat(c.values, lens), None, c.vocab)
        else:
            if np.array_equal(c.row_splits, sp):
                cols[p] = Column(c.values, None, c.vocab)
            else:
                raise TypeError(
                    f"flatten: {p!r} is repeated with a different shape")
    return ColumnBatch(_dyn_schema(batch.schema.name + "#flat", cols), cols,
                       n_new)


def apply_sort(batch: ColumnBatch, op: SortOp) -> ColumnBatch:
    v = eval_expr(op.expr, EvalContext(batch))
    order = np.argsort(v.values, kind="stable")
    if op.descending:
        order = order[::-1]
    return batch.gather(order)


def apply_limit(batch: ColumnBatch, k: int) -> ColumnBatch:
    if batch.n <= k:
        return batch
    return batch.gather(np.arange(k))


def apply_distinct(batch: ColumnBatch, expr: Optional[Expr]) -> ColumnBatch:
    if expr is not None:
        v = eval_expr(expr, EvalContext(batch))
        keys = hash_values(v.values, v.vocab)
    else:
        acc = np.zeros(batch.n, dtype=np.uint64)
        for p in batch.paths():
            c = batch[p]
            if c.is_repeated:
                continue
            acc ^= hash_values(c.values, c.vocab) * np.uint64(
                0x9E3779B97F4A7C15)
        keys = acc
    _, first = np.unique(keys, return_index=True)
    return batch.gather(np.sort(first))


def apply_model(batch: ColumnBatch, op: ModelApplyOp) -> ColumnBatch:
    ctx = EvalContext(batch)
    cols = dict(batch.columns)
    ins = {name: np.asarray(eval_expr(e, ctx).values)
           for name, e in op.inputs}
    pred = np.asarray(op.model.apply_columns(ins))
    if pred.shape[0] != batch.n:
        raise ValueError("model output row count mismatch")
    cols[op.output] = Column(pred.astype(np.float64)
                             if pred.dtype.kind == "f" else pred)
    return ColumnBatch(_dyn_schema(batch.schema.name + "#model", cols), cols,
                       batch.n)


# --------------------------------------------------------------------------
# Joins
# --------------------------------------------------------------------------

def apply_hash_join(left: ColumnBatch, right: CollectedTable,
                    left_key: Expr, alias: str) -> ColumnBatch:
    """Inner hash join: left rows × matching right rows (paper Table 1)."""
    lk = eval_expr(left_key, EvalContext(left))
    if lk.is_repeated:
        raise TypeError("join key must be singular")
    rows = right.lookup_rows(np.asarray(lk.values), lk.vocab)
    keep = np.nonzero(rows >= 0)[0]
    lbatch = left.gather(keep)
    rrows = rows[keep]
    cols = dict(lbatch.columns)
    for p, c in right.batch.columns.items():
        cols[f"{alias}.{p}"] = c.gather(rrows)
    return ColumnBatch(_dyn_schema(left.schema.name + "#join", cols), cols,
                       lbatch.n)


def apply_sub_flow(left: ColumnBatch, right_db: FDb, key: Expr,
                   index_path: str, alias: str) -> ColumnBatch:
    """Index join (paper ``sub_flow``): probe the right FDb's tag index per
    key instead of scanning it — one output row per (left row, right doc)."""
    lk = eval_expr(key, EvalContext(left))
    if lk.is_repeated:
        raise TypeError("sub_flow key must be singular")
    keys = np.asarray(lk.values)
    uniq = np.unique(keys)
    left_rows: List[np.ndarray] = []
    right_parts: List[ColumnBatch] = []
    for shard in right_db.shards:
        idx = shard.index(index_path, "tag")
        if idx is None:
            raise RuntimeError(f"sub_flow: no tag index on "
                               f"{right_db.name}.{index_path}")
        for u in uniq:
            u_val = (lk.vocab[int(u)] if lk.vocab is not None else u)
            bm = idx.lookup(u_val)
            ids = ids_from_bitmap(bm, shard.n)
            if ids.size == 0:
                continue
            lrows = np.nonzero(keys == u)[0]
            # cross product left-rows × right-docs
            left_rows.append(np.repeat(lrows, ids.size))
            right_parts.append(shard.batch.gather(
                np.tile(ids, lrows.size)))
    if not left_rows:
        empty_ids = np.zeros(0, dtype=np.int64)
        lbatch = left.gather(empty_ids)
        cols = dict(lbatch.columns)
        for p in right_db.shards[0].batch.paths():
            c = right_db.shards[0].batch[p]
            cols[f"{alias}.{p}"] = c.gather(empty_ids)
        return ColumnBatch(_dyn_schema(left.schema.name + "#subflow", cols),
                           cols, 0)
    lrows_all = np.concatenate(left_rows)
    rbatch = ColumnBatch.concat(right_parts)
    lbatch = left.gather(lrows_all)
    cols = dict(lbatch.columns)
    for p, c in rbatch.columns.items():
        cols[f"{alias}.{p}"] = c
    return ColumnBatch(_dyn_schema(left.schema.name + "#subflow", cols),
                       cols, lbatch.n)


def partition_by_hash(batch: ColumnBatch, key: Expr, num_parts: int
                      ) -> List[ColumnBatch]:
    """Sharder-style hash repartition (paper §4.3.5: "Sharders perform
    intermediate shuffles and joins")."""
    v = eval_expr(key, EvalContext(batch))
    h = hash_values(v.values, v.vocab)
    part = (h % np.uint64(num_parts)).astype(np.int64)
    return [batch.gather(np.nonzero(part == i)[0]) for i in range(num_parts)]


# --------------------------------------------------------------------------
# Distributed aggregation (aggregate_produce / aggregate_consume, §4.3.4)
# --------------------------------------------------------------------------

@dataclass
class AggPartial:
    """Mergeable per-shard aggregation state."""
    groups: Dict[tuple, List[Any]] = dc_field(default_factory=dict)


def _group_codes(key_arrays: List[np.ndarray], n: int
                 ) -> Tuple[np.ndarray, List[tuple]]:
    """Factorize per-row key tuples → (codes [n] int64, unique key tuples).

    The integer codes are what the segment-aggregation backends consume
    (the Pallas kernel's one-hot formulation runs over group codes).
    """
    if not key_arrays:
        if n == 0:
            return np.zeros(0, dtype=np.int64), []
        return np.zeros(n, dtype=np.int64), [()]
    # integer-like keys only: np.unique would collapse float NaN keys into
    # one group, unlike dict identity (NaN != NaN → one group per row)
    if len(key_arrays) == 1 and key_arrays[0].dtype.kind in "biu":
        uniq, inv = np.unique(key_arrays[0], return_inverse=True)
        return (inv.reshape(-1).astype(np.int64),
                [(v,) for v in uniq.tolist()])
    mapping: Dict[tuple, int] = {}
    codes = np.empty(n, dtype=np.int64)
    for i, k in enumerate(zip(*(a.tolist() for a in key_arrays))):
        codes[i] = mapping.setdefault(k, len(mapping))
    return codes, list(mapping)


@dataclass
class _AggPrep:
    """Host-side per-shard aggregation state, ready for segment dispatch.

    Splitting ``aggregate_produce`` into prepare → segment → finalize lets
    the wave runner batch the segment dispatch across shards (one kernel
    launch per wave) while the per-shard path keeps its original shape.
    """
    codes: np.ndarray                       # int64 [n], group code per row
    uniq_keys: List[tuple]
    counts: np.ndarray                      # int64 [n_groups]
    vals_list: List[Optional[np.ndarray]]   # per agg, None for count
    vocabs: List[Optional[list]]
    seg_arrays: List[np.ndarray]            # distinct columns needing (s,s2)
    seg_slot: List[Optional[int]]           # per agg → index into seg_arrays

    @property
    def n_groups(self) -> int:
        return len(self.uniq_keys)


def _agg_prepare(batch: ColumnBatch, spec: AggSpec) -> Optional[_AggPrep]:
    """Evaluate keys and agg inputs; None when the shard has no groups."""
    ctx = EvalContext(batch)
    key_arrays: List[np.ndarray] = []
    for _, e in spec.keys:
        v = eval_expr(e, ctx)
        if v.is_repeated:
            raise TypeError("group key must be singular")
        vals = np.asarray(v.values)
        if v.vocab is not None:
            vals = np.asarray(v.vocab, dtype=object)[vals]
        key_arrays.append(vals)
    codes, uniq_keys = _group_codes(key_arrays, batch.n)
    if not uniq_keys:
        return None
    counts = np.bincount(codes, minlength=len(uniq_keys))

    vals_list: List[Optional[np.ndarray]] = []
    vocabs: List[Optional[list]] = []
    eval_cache: Dict[str, Tuple[np.ndarray, Optional[list]]] = {}
    for kind, name, e in spec.aggs:
        if e is None:
            vals_list.append(None)
            vocabs.append(None)
            continue
        ekey = repr(e)     # avg+std_dev over the same expr share one eval
        if ekey not in eval_cache:
            v = eval_expr(e, ctx)
            if v.is_repeated:
                raise TypeError(f"aggregate input {name!r} must be singular")
            arr = np.asarray(v.values)
            if arr.ndim == 0:
                arr = np.broadcast_to(arr, (batch.n,))
            eval_cache[ekey] = (arr, v.vocab)
        arr, voc = eval_cache[ekey]
        vals_list.append(arr)
        vocabs.append(voc)

    # count/sum/sumsq route through the backend's segment aggregation
    # (numpy bincount, or the segment_agg kernel via kernels.ops); order
    # statistics and sketches need per-group row sets and stay on host.
    # One dispatch slot per distinct value column, not per agg.
    seg_arrays: List[np.ndarray] = []
    slot_by_id: Dict[int, int] = {}
    seg_slot: List[Optional[int]] = []
    for (kind, _, _), arr in zip(spec.aggs, vals_list):
        if kind in ("sum", "avg", "std_dev"):
            if id(arr) not in slot_by_id:
                slot_by_id[id(arr)] = len(seg_arrays)
                seg_arrays.append(arr)
            seg_slot.append(slot_by_id[id(arr)])
        else:
            seg_slot.append(None)
    return _AggPrep(codes, uniq_keys, counts, vals_list, vocabs,
                    seg_arrays, seg_slot)


def _agg_finalize(prep: _AggPrep, spec: AggSpec,
                  seg_results: List[Tuple[np.ndarray, np.ndarray]],
                  backend=None) -> AggPartial:
    """(s, s2) per segment slot + host order stats/sketches → AggPartial."""
    codes, counts, n_groups = prep.codes, prep.counts, prep.n_groups
    rows_by_group: Optional[List[np.ndarray]] = None

    def _rows() -> List[np.ndarray]:
        nonlocal rows_by_group
        if rows_by_group is None:
            order = np.argsort(codes, kind="stable")
            bounds = np.zeros(n_groups + 1, dtype=np.int64)
            np.cumsum(counts, out=bounds[1:])
            rows_by_group = [order[bounds[g]:bounds[g + 1]]
                             for g in range(n_groups)]
        return rows_by_group

    per_agg: List[List[Any]] = []
    for (kind, name, e), arr, voc, slot in zip(spec.aggs, prep.vals_list,
                                               prep.vocabs, prep.seg_slot):
        if kind == "count":
            per_agg.append([int(c) for c in counts])
        elif kind in ("sum", "avg", "std_dev"):
            s, s2 = seg_results[slot]
            if kind == "sum":
                per_agg.append([float(x) for x in s])
            elif kind == "avg":
                per_agg.append([(float(x), int(c))
                                for x, c in zip(s, counts)])
            else:
                per_agg.append([(float(x), float(y), int(c))
                                for x, y, c in zip(s, s2, counts)])
        elif kind == "min":
            per_agg.append([float(arr[r].min()) for r in _rows()])
        elif kind == "max":
            per_agg.append([float(arr[r].max()) for r in _rows()])
        elif kind == "approx_distinct":
            # grouped sketch build as ONE segment-max through the backend
            # seam: per-row (register index, rank) pairs scatter-max into
            # per-group register planes — byte-equal to building each
            # group's HyperLogLog from its row set, and partition-
            # invariant because register max is commutative + idempotent
            hll_p = HyperLogLog().p
            idx, rank = hll_register_rows(hash_values(arr, voc), hll_p)
            regs = as_backend(backend).segment_hll(
                codes, idx, rank, n_groups, 1 << hll_p)
            per_agg.append([HyperLogLog(hll_p, regs[g].copy())
                            for g in range(n_groups)])
        else:
            raise ValueError(kind)

    part = AggPartial()
    for g, k in enumerate(prep.uniq_keys):
        part.groups[k] = [col[g] for col in per_agg]
    return part


def aggregate_produce(batch: ColumnBatch, spec: AggSpec,
                      backend=None) -> AggPartial:
    backend = as_backend(backend)
    prep = _agg_prepare(batch, spec)
    if prep is None:
        return AggPartial()
    seg_results = []
    for arr in prep.seg_arrays:
        _, s, s2 = backend.segment_aggregate(prep.codes, arr, prep.n_groups)
        seg_results.append((s, s2))
    return _agg_finalize(prep, spec, seg_results, backend=backend)


def aggregate_produce_batched(batches: Sequence[ColumnBatch], spec: AggSpec,
                              backend=None) -> List[AggPartial]:
    """Per-shard partials for a wave with one segment dispatch per value
    column across the whole wave (instead of one per shard) — byte-equal
    to running :func:`aggregate_produce` shard by shard."""
    backend = as_backend(backend)
    preps = [_agg_prepare(b, spec) for b in batches]
    live = [p for p in preps if p is not None]
    n_slots = len(live[0].seg_arrays) if live else 0
    seg_by_prep: Dict[int, List[Tuple[np.ndarray, np.ndarray]]] = {
        id(p): [] for p in live}
    for slot in range(n_slots):
        results = backend.segment_aggregate_batched(
            [p.codes for p in live],
            [p.seg_arrays[slot] for p in live],
            [p.n_groups for p in live])
        for p, (_, s, s2) in zip(live, results):
            seg_by_prep[id(p)].append((s, s2))
    return [AggPartial() if p is None
            else _agg_finalize(p, spec, seg_by_prep[id(p)], backend=backend)
            for p in preps]


def merge_agg_partials(parts: Sequence[AggPartial], spec: AggSpec
                       ) -> AggPartial:
    out = AggPartial()
    for p in parts:
        for k, accs in p.groups.items():
            if k not in out.groups:
                out.groups[k] = [a if not isinstance(a, HyperLogLog)
                                 else HyperLogLog(a.p, a.registers.copy())
                                 for a in accs]
                continue
            cur = out.groups[k]
            for i, (kind, name, e) in enumerate(spec.aggs):
                if kind == "count":
                    cur[i] += accs[i]
                elif kind == "sum":
                    cur[i] += accs[i]
                elif kind == "avg":
                    cur[i] = (cur[i][0] + accs[i][0], cur[i][1] + accs[i][1])
                elif kind == "std_dev":
                    cur[i] = (cur[i][0] + accs[i][0],
                              cur[i][1] + accs[i][1],
                              cur[i][2] + accs[i][2])
                elif kind == "min":
                    cur[i] = min(cur[i], accs[i])
                elif kind == "max":
                    cur[i] = max(cur[i], accs[i])
                elif kind == "approx_distinct":
                    cur[i].merge(accs[i])
    return out


def aggregate_consume(part: AggPartial, spec: AggSpec) -> ColumnBatch:
    """Finish accumulators → output batch (runs on the Mixer)."""
    keys = sorted(part.groups.keys(), key=lambda t: tuple(map(str, t)))
    n = len(keys)
    cols: Dict[str, Column] = {}
    for j, (name, _) in enumerate(spec.keys):
        col_vals = [k[j] for k in keys]
        if col_vals and isinstance(col_vals[0], str):
            cols[name] = Column.from_strings(col_vals)
        else:
            cols[name] = Column(np.asarray(col_vals))
    for i, (kind, name, e) in enumerate(spec.aggs):
        accs = [part.groups[k][i] for k in keys]
        if kind == "count":
            cols[name] = Column(np.asarray(accs, dtype=np.int64))
        elif kind in ("sum", "min", "max"):
            cols[name] = Column(np.asarray(accs, dtype=np.float64))
        elif kind == "avg":
            cols[name] = Column(np.asarray(
                [s / max(c, 1) for s, c in accs], dtype=np.float64))
        elif kind == "std_dev":
            out = []
            for s, s2, c in accs:
                m = s / max(c, 1)
                out.append(np.sqrt(max(s2 / max(c, 1) - m * m, 0.0)))
            cols[name] = Column(np.asarray(out, dtype=np.float64))
        elif kind == "approx_distinct":
            cols[name] = Column(np.asarray([h.estimate() for h in accs],
                                           dtype=np.float64))
    return ColumnBatch(_dyn_schema("agg", cols), cols, n)


# --------------------------------------------------------------------------
# Server-side record pipeline
# --------------------------------------------------------------------------

def run_record_ops(batch: ColumnBatch, ops: Sequence[Op], catalog,
                   collected_cache: Optional[Dict[int, CollectedTable]] = None,
                   backend=None) -> ColumnBatch:
    """Run record-parallel ops on one shard's (already index-selected) batch."""
    for op in ops:
        if isinstance(op, MapOp):
            batch = apply_map(batch, op.make)
        elif isinstance(op, FilterOp):
            batch = apply_filter(batch, op.pred, backend)
        elif isinstance(op, FlattenOp):
            batch = apply_flatten(batch, op.path)
        elif isinstance(op, ModelApplyOp):
            batch = apply_model(batch, op)
        elif isinstance(op, JoinOp):
            table = collected_cache[id(op)] if collected_cache else None
            if table is None:
                raise RuntimeError("join table missing from broadcast cache")
            batch = apply_hash_join(batch, table, op.left_key, op.alias)
        elif isinstance(op, SubFlowOp):
            batch = apply_sub_flow(batch, catalog.get(op.right_fdb), op.key,
                                   op.index_path, op.alias)
        else:
            raise TypeError(f"non-record op on server: {type(op).__name__}")
    return batch
