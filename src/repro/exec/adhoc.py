"""Warp:AdHoc — the interactive execution engine (paper §4.3.1–§4.3.5).

Clients hand a WFL flow to the *Mixer*, which plans the query, acquires a
micro-cluster of *Servers* from the Catalog manager (execution isolation),
fans shard tasks out, and merges partial results.  Failure handling is
"best effort": a failed server task is retried once, then dropped — the
result reports its *coverage* so the client can decide to retry, exactly
the Dremel-style contract the paper describes for interactive queries.

Per-query profiles (rows scanned, bytes read, CPU/exec time) are appended
to a streaming FDb (§4.1.1: "read-write FDbs … for query profiling"), which
the benchmark harness queries back — with WarpFlow itself.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor, as_completed
from dataclasses import dataclass, field as dc_field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ..core.exprs import CollectedTable, FieldRef
from ..core.flow import (AggregateOp, DistinctOp, Flow, JoinOp, LimitOp,
                         SortOp)
from ..core.planner import PartitionPlan, Plan, plan_flow
from ..fdb.columnar import ColumnBatch
from ..fdb.fdb import FDb, Shard, _build_shard_indexes
from ..fdb.schema import DOUBLE, INT, STRING, Schema
from .backend import as_backend
from .batched import (merge_partition_partials, partition_waves,
                      resolve_partition_plan, run_wave_task, wave_size)
from .config import ExecConfig
from .catalog import Catalog, default_catalog
from .failures import FaultPlan, TaskFailure
from .processors import (AggPartial, aggregate_consume, aggregate_produce,
                         apply_distinct, apply_filter, apply_limit,
                         apply_sort, merge_agg_partials, run_record_ops)
from .task import ShardPartial as _ShardPartial, run_shard_task

__all__ = ["AdHocEngine", "QueryResult", "default_engine"]


@dataclass
class QueryProfile:
    source: str = ""
    shards_total: int = 0
    shards_done: int = 0
    rows_scanned: int = 0
    rows_selected: int = 0
    bytes_read: int = 0
    cpu_ms: float = 0.0
    io_ms: float = 0.0
    exec_ms: float = 0.0
    retries: int = 0
    dropped_shards: List[int] = dc_field(default_factory=list)

    @property
    def coverage(self) -> float:
        return self.shards_done / max(self.shards_total, 1)

    def record(self) -> dict:
        return {"source": self.source, "shards_total": self.shards_total,
                "shards_done": self.shards_done,
                "rows_scanned": self.rows_scanned,
                "rows_selected": self.rows_selected,
                "bytes_read": self.bytes_read, "cpu_ms": self.cpu_ms,
                "io_ms": self.io_ms, "exec_ms": self.exec_ms,
                "retries": self.retries}


class QueryResult(CollectedTable):
    def __init__(self, batch: ColumnBatch, profile: QueryProfile,
                 plan: Plan):
        super().__init__(batch)
        self.profile = profile
        self.plan = plan

    @property
    def coverage(self) -> float:
        return self.profile.coverage


class AdHocEngine:
    """Mixer + Servers over a thread pool (the always-on micro-cluster)."""

    PROFILE_SCHEMA = Schema.dynamic("warpflow.query_log", {
        "source": STRING, "shards_total": INT, "shards_done": INT,
        "rows_scanned": INT, "rows_selected": INT, "bytes_read": INT,
        "cpu_ms": DOUBLE, "io_ms": DOUBLE, "exec_ms": DOUBLE,
        "retries": INT})

    def __init__(self, catalog: Optional[Catalog] = None,
                 num_servers: int = 8,
                 profile_log=None, backend=None,
                 wave: Optional[int] = None,
                 partitions: Optional[int] = None,
                 config: Optional[ExecConfig] = None):
        self.catalog = catalog or default_catalog()
        self.num_servers = num_servers
        # one consolidated config (see exec.config): explicit config
        # fields > legacy per-field kwargs (deprecation shims) > env >
        # defaults.  The resolved values keep their legacy attributes.
        self.config = (config or ExecConfig()).fill(
            backend=backend, wave=wave, partitions=partitions)
        self.backend = self.config.resolve_backend()
        self.wave = self.config.resolve_wave(self.backend)
        self.partitions = self.config.partitions
        if profile_log is None:
            from ..fdb.streaming import StreamingFDb
            profile_log = StreamingFDb("warpflow.query_log",
                                       self.PROFILE_SCHEMA,
                                       flush_threshold=256)
        self.profile_log = profile_log

    # ------------------------------------------------------------- public
    def collect(self, flow: Flow, fault_plan: Optional[FaultPlan] = None,
                num_servers: Optional[int] = None) -> QueryResult:
        t0 = time.perf_counter()
        plan = plan_flow(flow, self.catalog)
        # execute against the snapshot the planner pinned: for streaming
        # sources a concurrent append swaps the catalog's current view,
        # and a re-resolve here could tear the query across generations
        db = plan.db if plan.db is not None else self.catalog.get(plan.source)
        # device-resident columns: one-time put per FDb (no-op on host
        # backends; for a streaming snapshot only new delta buffers
        # upload — shared sealed shards are already resident)
        self.backend.prime_fdb(db)

        # Broadcast side of hash joins: run the right flow first (recursive
        # query), index it by the right key — the paper's broadcast join.
        tables: Dict[int, CollectedTable] = {}
        for op in plan.server_ops:
            if isinstance(op, JoinOp):
                rres = self.collect(op.right, fault_plan=fault_plan)
                if not isinstance(op.right_key, FieldRef):
                    raise TypeError("join right_key must be a field")
                tables[id(op)] = rres.to_dict(op.right_key.path)

        want = min(len(plan.shard_ids), num_servers or self.num_servers)
        grant = self.catalog.resources.acquire(want)
        profile = QueryProfile(source=plan.source,
                               shards_total=len(plan.shard_ids))
        pplan = self._partition_plan(plan, profile, fault_plan)
        try:
            partials = self._run_servers(db, plan, tables, grant, profile,
                                         fault_plan, pplan)
        finally:
            self.catalog.resources.release(grant)

        batch = self._mixer(plan, partials, profile,
                            premerged=merge_partition_partials(
                                db, plan, partials, self.backend, pplan))
        profile.exec_ms = (time.perf_counter() - t0) * 1e3
        self.profile_log.append(profile.record())
        return QueryResult(batch, profile, plan)

    def save(self, flow: Flow, name: str, num_shards: int = 8,
             schema: Optional[Schema] = None, **kw) -> FDb:
        """Materialize a flow back into a registered FDb (Table 1: save)."""
        res = self.collect(flow, **kw)
        batch = res.batch
        if schema is not None:
            # re-index under the provided (annotated) schema
            from ..fdb.fdb import build_fdb
            db = build_fdb(name, schema, batch.to_records(), num_shards)
        else:
            ids = np.arange(batch.n)
            shards = []
            for i in range(num_shards):
                sub = batch.gather(ids[ids % num_shards == i])
                shards.append(Shard(sub, _build_shard_indexes(sub.schema,
                                                              sub)))
            db = FDb(name, batch.schema, shards)
        self.catalog.register(db)
        return db

    def explain(self, flow: Flow) -> str:
        return plan_flow(flow, self.catalog).describe()

    # ------------------------------------------------------------ servers
    def _partition_plan(self, plan, profile=None,
                        fault_plan=None) -> PartitionPlan:
        """See ``batched.resolve_partition_plan`` — the engines share the
        partition-axis resolution and fault-reroute path."""
        return resolve_partition_plan(self.partitions, self.backend, plan,
                                      fault_plan, profile)

    def _run_partition_wave(self, pplan, pi, db, plan, sids, nxt, tables,
                            fault_plan):
        with self.backend.partition_context(pi, pplan.num_partitions):
            return run_wave_task(db, plan, sids, tables, self.catalog,
                                 fault_plan, backend=self.backend,
                                 prefetch_sids=nxt,
                                 fused=self.config.fused,
                                 profile=self.config.profile)

    def _run_servers(self, db, plan, tables, grant, profile, fault_plan,
                     pplan: Optional[PartitionPlan] = None
                     ) -> List[_ShardPartial]:
        """Per-partition waves of shards through the batched backend
        seam; shards whose fault check trips at wave start fall back to
        the per-shard retry/drop path (best-effort contract unchanged).
        With P=1 this degenerates to the legacy single-loop wave order,
        byte for byte."""
        partials: List[_ShardPartial] = []
        retry: List[int] = []
        if pplan is None:
            pplan = self._partition_plan(plan, profile, fault_plan)
        # each wave names its successor *within its partition* so a fused
        # backend stages wave k+1's buffers on that partition's device
        # while wave k computes
        subs = []
        for pi, part in enumerate(pplan.parts):
            pw = partition_waves(part, self.wave)
            for j, w in enumerate(pw):
                subs.append((pi, w, pw[j + 1] if j + 1 < len(pw)
                             else None))
        with ThreadPoolExecutor(max_workers=grant) as pool:
            futs = [pool.submit(self._run_partition_wave, pplan, pi, db,
                                plan, w, nxt, tables, fault_plan)
                    for pi, w, nxt in subs]
            for f in as_completed(futs):
                done, failed = f.result()
                partials.extend(done)
                profile.shards_done += len(done)
                retry.extend(failed)
            # best-effort: one retry round, then drop (client may re-issue)
            for sid in sorted(retry):
                profile.retries += 1
                try:
                    partials.append(run_shard_task(
                        db, plan, sid, tables, self.catalog, fault_plan,
                        backend=self.backend))
                    profile.shards_done += 1
                except TaskFailure:
                    profile.dropped_shards.append(sid)
        for p in partials:
            profile.rows_scanned += p.rows_scanned
            profile.rows_selected += p.rows_selected
            profile.bytes_read += p.bytes_read
            profile.cpu_ms += p.cpu_ms
            profile.io_ms += p.io_ms
        # deterministic reduction order regardless of completion order
        partials.sort(key=lambda p: p.shard_id)
        return partials

    # -------------------------------------------------------------- mixer
    def _mixer(self, plan: Plan, partials: Sequence[_ShardPartial],
               profile: QueryProfile,
               premerged: Optional[AggPartial] = None) -> ColumnBatch:
        mixer_ops = list(plan.mixer_ops)
        if mixer_ops and isinstance(mixer_ops[0], AggregateOp):
            spec = mixer_ops[0].spec
            # ``premerged`` is the partition layer's single-launch device
            # combine of the per-shard segment states; when absent, fold
            # host-side in shard-id order (P-invariant either way)
            merged = premerged if premerged is not None else \
                merge_agg_partials(
                    [p.agg for p in partials if p.agg is not None], spec)
            batch = aggregate_consume(merged, spec)
            mixer_ops = mixer_ops[1:]
        else:
            batches = [p.batch for p in partials if p.batch is not None]
            if batches:
                batch = ColumnBatch.concat(batches)
            else:
                batch = ColumnBatch(plan.out_schema, {}, 0)
        for op in mixer_ops:
            if isinstance(op, SortOp):
                batch = apply_sort(batch, op)
            elif isinstance(op, LimitOp):
                batch = apply_limit(batch, op.k)
            elif isinstance(op, DistinctOp):
                batch = apply_distinct(batch, op.expr)
            elif isinstance(op, AggregateOp):
                part = aggregate_produce(batch, op.spec, self.backend)
                batch = aggregate_consume(part, op.spec)
            else:
                batch = run_record_ops(batch, [op], self.catalog, None,
                                       backend=self.backend)
        return batch


_DEFAULT_ENGINE: Optional[AdHocEngine] = None


def default_engine() -> AdHocEngine:
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = AdHocEngine()
    return _DEFAULT_ENGINE
