"""Data de-noising (paper §4.1.3, Figure 6).

Smartphone location fixes are 3–30 m off; the paper turns a noisy point into
a *probabilistic location* (mean + confidence radius → circular area) and a
noisy trace into a *probabilistic path* (time-ordered curvilinear strip),
then snaps them onto a well-defined space (POIs, road segments) with a
scored model.

We reproduce:
  * ``prob_location`` / ``prob_path`` — the area representations, built on
    :class:`repro.geo.areatree.AreaTree` so fuzzy selections compose with the
    area index.
  * ``snap_points`` — point → nearest candidate, scored by a Gaussian
    distance likelihood × a popularity prior (the paper's "popularity of
    places" signal).  Scoring is vectorized jnp so it can run inside WFL
    ``map()`` stages and, per §5, be swapped for a learned model.
  * ``snap_path`` — trace → road-segment sequence via Viterbi over an HMM
    whose emissions are distance likelihoods and whose transitions penalize
    discontinuity (the standard map-matching formulation, vectorized).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .areatree import AreaTree
from .geometry import point_segment_dist

__all__ = ["prob_location", "prob_path", "snap_points", "snap_path",
           "SnapModel"]


def prob_location(ix: int, iy: int, accuracy_m: float, meters_per_unit: float,
                  max_level: int = 8) -> AreaTree:
    """Probabilistic location: mean point + confidence radius → circular area."""
    r_units = max(accuracy_m / meters_per_unit, 1.0)
    return AreaTree.from_circle(ix, iy, r_units, max_level=max_level)


def prob_path(xs, ys, accuracy_m: float, meters_per_unit: float,
              max_level: int = 7) -> AreaTree:
    """Probabilistic path: waypoints + noise strength → envelope strip.

    Note (paper): this is *not* the bbox of the points — it is an envelope
    around the path, so time ordering is preserved by construction.
    """
    w_units = max(accuracy_m / meters_per_unit, 1.0)
    return AreaTree.from_path(xs, ys, w_units, max_level=max_level)


@dataclass
class SnapModel:
    """Scoring model for snapping: Gaussian distance × popularity prior.

    ``sigma_m`` is the expected GPS noise.  ``w_dist``/``w_pop`` are log-space
    weights — a learned replacement (paper §5) only has to produce the same
    log-score interface.
    """

    sigma_m: float = 15.0
    w_dist: float = 1.0
    w_pop: float = 0.25

    def log_score(self, dist_m, popularity):
        d = jnp.asarray(dist_m, dtype=jnp.float32)
        p = jnp.asarray(popularity, dtype=jnp.float32)
        return (-self.w_dist * 0.5 * (d / self.sigma_m) ** 2
                + self.w_pop * jnp.log1p(p))


def snap_points(px, py, cand_x, cand_y, cand_pop, meters_per_unit: float,
                model: SnapModel | None = None,
                max_dist_m: float = 100.0) -> Tuple[np.ndarray, np.ndarray]:
    """Snap each noisy point to the best candidate POI.

    Returns (candidate index per point, log-score); index −1 where no
    candidate is within ``max_dist_m``.
    """
    model = model or SnapModel()
    px = jnp.asarray(np.asarray(px, dtype=np.float64) * meters_per_unit,
                     dtype=jnp.float32)
    py = jnp.asarray(np.asarray(py, dtype=np.float64) * meters_per_unit,
                     dtype=jnp.float32)
    cx = jnp.asarray(np.asarray(cand_x, dtype=np.float64) * meters_per_unit,
                     dtype=jnp.float32)
    cy = jnp.asarray(np.asarray(cand_y, dtype=np.float64) * meters_per_unit,
                     dtype=jnp.float32)
    pop = jnp.asarray(cand_pop, dtype=jnp.float32)

    d = jnp.sqrt((px[:, None] - cx[None, :]) ** 2
                 + (py[:, None] - cy[None, :]) ** 2)          # [P, C] meters
    score = model.log_score(d, pop[None, :])
    score = jnp.where(d <= max_dist_m, score, -jnp.inf)
    best = jnp.argmax(score, axis=1)
    best_score = jnp.max(score, axis=1)
    best = jnp.where(jnp.isfinite(best_score), best, -1)
    return np.asarray(best), np.asarray(best_score)


def snap_path(px, py, seg_ax, seg_ay, seg_bx, seg_by, seg_pop,
              meters_per_unit: float, model: SnapModel | None = None,
              transition_scale_m: float = 50.0) -> np.ndarray:
    """Map-match a noisy trace to road segments (paper Fig. 6).

    HMM over (waypoint × segment): emission = Gaussian distance likelihood ×
    popularity prior; transition penalizes hopping between far-apart
    segments.  Viterbi is a ``lax.scan`` over waypoints with a [S]-state
    value vector — O(T·S²) vectorized.

    Returns the best segment index per waypoint.
    """
    model = model or SnapModel()
    mpu = meters_per_unit
    # Emission distances: waypoints × segments, meters.
    d = point_segment_dist(
        np.asarray(px, dtype=np.float64)[:, None],
        np.asarray(py, dtype=np.float64)[:, None],
        np.asarray(seg_ax, dtype=np.float64)[None, :],
        np.asarray(seg_ay, dtype=np.float64)[None, :],
        np.asarray(seg_bx, dtype=np.float64)[None, :],
        np.asarray(seg_by, dtype=np.float64)[None, :]) * mpu
    emit = np.asarray(
        SnapModel.log_score(model, d, np.asarray(seg_pop)[None, :]))

    # Transition: distance between segment midpoints.
    mx = (np.asarray(seg_ax, dtype=np.float64)
          + np.asarray(seg_bx, dtype=np.float64)) / 2 * mpu
    my = (np.asarray(seg_ay, dtype=np.float64)
          + np.asarray(seg_by, dtype=np.float64)) / 2 * mpu
    hop = np.hypot(mx[:, None] - mx[None, :], my[:, None] - my[None, :])
    trans = jnp.asarray(-hop / transition_scale_m, dtype=jnp.float32)  # [S,S]

    emit_j = jnp.asarray(emit, dtype=jnp.float32)                      # [T,S]

    def step(carry, e_t):
        # carry: [S] best log-prob ending in each state
        cand = carry[:, None] + trans                                  # [S,S]
        best_prev = jnp.argmax(cand, axis=0)                           # [S]
        val = jnp.max(cand, axis=0) + e_t
        return val, best_prev

    v0 = emit_j[0]
    vT, back = jax.lax.scan(step, v0, emit_j[1:])
    back = np.asarray(back)                                            # [T-1,S]
    T = emit.shape[0]
    out = np.zeros(T, dtype=np.int64)
    out[-1] = int(np.argmax(np.asarray(vT)))
    for t in range(T - 2, -1, -1):
        out[t] = back[t, out[t + 1]]
    return out
