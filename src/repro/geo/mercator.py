"""Integer spherical-Mercator projection (paper §4.1.2 ``location`` index).

The paper stores locations as an integer representation of the Mercator
projection with "a precision of several centimeters".  We use a 30-bit grid
per axis: the Earth's Mercator square is divided into 2^30 × 2^30 cells,
giving a cell edge of 40075 km / 2^30 ≈ 3.7 cm at the equator.

Latitudes above 85.05°N / below 85.05°S are not representable (paper: "not
indexable without some translation"); they are clamped by default and can be
made to raise instead.

Morton (Z-order) keys interleave the two 30-bit coordinates into a 60-bit
key.  Six bits per level (3 x-bits + 3 y-bits) make a Morton prefix exactly
an *area-tree* cell (8×8 split per node — paper §4.1.2 ``area``), so one key
space serves both the location index and the area index.
"""
from __future__ import annotations

import numpy as np

MAX_LEVEL = 10                  # 6 bits/level * 10 levels = 60-bit Morton keys
BITS_PER_AXIS = 3 * MAX_LEVEL   # 30
GRID = np.uint64(1) << np.uint64(BITS_PER_AXIS)          # 2**30 cells/axis
EARTH_CIRCUMFERENCE_M = 40_075_016.686                    # equatorial, meters
METERS_PER_CELL = EARTH_CIRCUMFERENCE_M / float(GRID)     # ≈ 0.0373 m
MAX_MERCATOR_LAT = 85.05112877980659                      # atan(sinh(pi))

__all__ = [
    "MAX_LEVEL", "BITS_PER_AXIS", "GRID", "METERS_PER_CELL", "MAX_MERCATOR_LAT",
    "latlng_to_xy", "xy_to_latlng", "interleave", "deinterleave",
    "latlng_to_morton", "morton_to_latlng", "cell_of", "cell_range",
    "meters_per_unit_at", "EARTH_CIRCUMFERENCE_M",
]


def latlng_to_xy(lat, lng, *, clamp: bool = True):
    """Project (lat, lng) degrees → integer Mercator (ix, iy), vectorized.

    Returns uint64 arrays in [0, 2^30).  ``iy`` grows *southwards* (standard
    web-Mercator tile convention).
    """
    lat = np.asarray(lat, dtype=np.float64)
    lng = np.asarray(lng, dtype=np.float64)
    if clamp:
        lat = np.clip(lat, -MAX_MERCATOR_LAT, MAX_MERCATOR_LAT)
    elif np.any(np.abs(lat) > MAX_MERCATOR_LAT):
        raise ValueError("latitude outside Mercator-indexable range (±85.05°)")
    x = (lng / 360.0 + 0.5) % 1.0
    lat_r = np.radians(lat)
    y = 0.5 - np.log(np.tan(lat_r) + 1.0 / np.cos(lat_r)) / (2.0 * np.pi)
    n = float(GRID)
    ix = np.minimum((x * n).astype(np.uint64), GRID - np.uint64(1))
    iy = np.minimum(np.maximum(y, 0.0) * n, n - 1).astype(np.uint64)
    return ix, iy


def xy_to_latlng(ix, iy):
    """Inverse projection: integer Mercator cell *centers* → (lat, lng) degrees."""
    n = float(GRID)
    x = (np.asarray(ix, dtype=np.float64) + 0.5) / n
    y = (np.asarray(iy, dtype=np.float64) + 0.5) / n
    lng = (x - 0.5) * 360.0
    lat = np.degrees(np.arctan(np.sinh((0.5 - y) * 2.0 * np.pi)))
    return lat, lng


def _spread3(v: np.ndarray) -> np.ndarray:
    """Spread the low 30 bits of v so bit i lands at position 2*i (uint64)."""
    v = v.astype(np.uint64)
    v = (v | (v << np.uint64(16))) & np.uint64(0x0000FFFF0000FFFF)
    v = (v | (v << np.uint64(8))) & np.uint64(0x00FF00FF00FF00FF)
    v = (v | (v << np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    v = (v | (v << np.uint64(2))) & np.uint64(0x3333333333333333)
    v = (v | (v << np.uint64(1))) & np.uint64(0x5555555555555555)
    return v


def _unspread3(v: np.ndarray) -> np.ndarray:
    v = v.astype(np.uint64) & np.uint64(0x5555555555555555)
    v = (v | (v >> np.uint64(1))) & np.uint64(0x3333333333333333)
    v = (v | (v >> np.uint64(2))) & np.uint64(0x0F0F0F0F0F0F0F0F)
    v = (v | (v >> np.uint64(4))) & np.uint64(0x00FF00FF00FF00FF)
    v = (v | (v >> np.uint64(8))) & np.uint64(0x0000FFFF0000FFFF)
    v = (v | (v >> np.uint64(16))) & np.uint64(0x00000000FFFFFFFF)
    return v


def interleave(ix, iy) -> np.ndarray:
    """Morton-interleave two 30-bit coords → 60-bit key (x in even bits)."""
    return _spread3(np.asarray(ix)) | (_spread3(np.asarray(iy)) << np.uint64(1))


def deinterleave(key):
    key = np.asarray(key, dtype=np.uint64)
    return _unspread3(key), _unspread3(key >> np.uint64(1))


def latlng_to_morton(lat, lng, *, clamp: bool = True) -> np.ndarray:
    ix, iy = latlng_to_xy(lat, lng, clamp=clamp)
    return interleave(ix, iy)


def morton_to_latlng(key):
    ix, iy = deinterleave(key)
    return xy_to_latlng(ix, iy)


def cell_of(key, level: int) -> np.ndarray:
    """Area-tree cell id containing ``key`` at ``level`` (Morton prefix).

    A level-``l`` cell is identified by its 6*l-bit Morton prefix, left-aligned
    in the 60-bit key space (so cell ids at any level sort in Morton order).
    """
    if not 0 <= level <= MAX_LEVEL:
        raise ValueError(f"level must be in [0, {MAX_LEVEL}]")
    shift = np.uint64(6 * (MAX_LEVEL - level))
    return (np.asarray(key, dtype=np.uint64) >> shift) << shift


def cell_range(cell, level: int):
    """[lo, hi) Morton-key range covered by a level-``level`` cell id."""
    shift = np.uint64(6 * (MAX_LEVEL - level))
    lo = np.asarray(cell, dtype=np.uint64)
    return lo, lo + (np.uint64(1) << shift)


def meters_per_unit_at(lat) -> np.ndarray:
    """Ground meters per integer-Mercator unit at a given latitude.

    Mercator stretches by 1/cos(lat); ground distance shrinks accordingly.
    """
    return METERS_PER_CELL * np.cos(np.radians(np.asarray(lat, dtype=np.float64)))
