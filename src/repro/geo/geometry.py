"""Geospatial toolkit (paper §4.2.2: distance estimation, projections, …).

Host-side numpy utilities plus jnp device variants where the query engine
evaluates expressions over columns.  All device-side geometry works in
integer-Mercator space (float64 is unavailable on TPU; we use float32 deltas
around shard-local origins to keep centimeter precision where it matters).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import mercator as M

EARTH_RADIUS_M = 6_371_008.8

__all__ = [
    "haversine_m", "polyline_length_m", "mercator_dist_m",
    "point_segment_dist", "bbox_of", "Box", "mercator_dist_m_jnp",
]


class Box:
    """Closed integer-Mercator bounding box."""

    __slots__ = ("x0", "y0", "x1", "y1")

    def __init__(self, x0: int, y0: int, x1: int, y1: int):
        self.x0, self.x1 = sorted((int(x0), int(x1)))
        self.y0, self.y1 = sorted((int(y0), int(y1)))

    @staticmethod
    def from_latlng(lat0, lng0, lat1, lng1) -> "Box":
        ix, iy = M.latlng_to_xy(np.array([lat0, lat1]), np.array([lng0, lng1]))
        return Box(int(ix[0]), int(iy[0]), int(ix[1]), int(iy[1]))

    def contains(self, ix, iy):
        ix = np.asarray(ix)
        iy = np.asarray(iy)
        return ((ix >= self.x0) & (ix <= self.x1)
                & (iy >= self.y0) & (iy <= self.y1))

    def center(self):
        return (self.x0 + self.x1) // 2, (self.y0 + self.y1) // 2

    def __repr__(self):
        return f"Box({self.x0},{self.y0},{self.x1},{self.y1})"


def haversine_m(lat0, lng0, lat1, lng1):
    """Great-circle distance in meters (vectorized, numpy)."""
    lat0, lng0, lat1, lng1 = (np.radians(np.asarray(a, dtype=np.float64))
                              for a in (lat0, lng0, lat1, lng1))
    dlat = lat1 - lat0
    dlng = lng1 - lng0
    h = (np.sin(dlat / 2.0) ** 2
         + np.cos(lat0) * np.cos(lat1) * np.sin(dlng / 2.0) ** 2)
    return 2.0 * EARTH_RADIUS_M * np.arcsin(np.sqrt(np.clip(h, 0.0, 1.0)))


def mercator_dist_m(ix0, iy0, ix1, iy1):
    """Euclidean distance in ground meters between integer-Mercator points.

    Uses the local Mercator scale at the midpoint latitude — accurate to
    well under 1% for distances up to tens of km (the paper's use cases).
    """
    ix0 = np.asarray(ix0, dtype=np.float64)
    iy0 = np.asarray(iy0, dtype=np.float64)
    ix1 = np.asarray(ix1, dtype=np.float64)
    iy1 = np.asarray(iy1, dtype=np.float64)
    mid_lat, _ = M.xy_to_latlng((ix0 + ix1) / 2, (iy0 + iy1) / 2)
    mpu = M.meters_per_unit_at(mid_lat)
    return np.hypot(ix1 - ix0, iy1 - iy0) * mpu


def mercator_dist_m_jnp(ix0, iy0, ix1, iy1, meters_per_unit):
    """Device-side distance: caller supplies the local Mercator scale."""
    dx = (ix1 - ix0).astype(jnp.float32)
    dy = (iy1 - iy0).astype(jnp.float32)
    return jnp.sqrt(dx * dx + dy * dy) * meters_per_unit


def polyline_length_m(xs, ys):
    """Ground length of a polyline given integer-Mercator vertices."""
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    if xs.size < 2:
        return 0.0
    return float(np.sum(mercator_dist_m(xs[:-1], ys[:-1], xs[1:], ys[1:])))


def point_segment_dist(px, py, ax, ay, bx, by):
    """Distance (in input units) from points to segments, broadcast."""
    px = np.asarray(px, dtype=np.float64)
    py = np.asarray(py, dtype=np.float64)
    dx = np.asarray(bx, dtype=np.float64) - ax
    dy = np.asarray(by, dtype=np.float64) - ay
    seg2 = np.maximum(dx * dx + dy * dy, 1e-12)
    t = np.clip(((px - ax) * dx + (py - ay) * dy) / seg2, 0.0, 1.0)
    ex = px - (ax + t * dx)
    ey = py - (ay + t * dy)
    return np.hypot(ex, ey)


def bbox_of(xs, ys) -> Box:
    xs = np.asarray(xs)
    ys = np.asarray(ys)
    return Box(int(xs.min()), int(ys.min()), int(xs.max()), int(ys.max()))
