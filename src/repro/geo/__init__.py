"""Geospatial substrate: integer Mercator, 64-way area trees, de-noising."""
from . import mercator
from .areatree import AreaTree, cover, OUT, PARTIAL, FULL
from .geometry import (Box, haversine_m, mercator_dist_m, polyline_length_m,
                       point_segment_dist, bbox_of)
from .denoise import prob_location, prob_path, snap_points, snap_path, SnapModel

__all__ = [
    "mercator", "AreaTree", "cover", "OUT", "PARTIAL", "FULL",
    "Box", "haversine_m", "mercator_dist_m", "polyline_length_m",
    "point_segment_dist", "bbox_of",
    "prob_location", "prob_path", "snap_points", "snap_path", "SnapModel",
]
