"""64-way area trees (paper §4.1.2, Figure 5).

The paper indexes geospatial regions with *area trees*: quad-trees whose
nodes split 8×8 (=64 children), matching the spherical-Mercator gridding.
Because our Morton keys use 6 bits per level (3 x-bits + 3 y-bits), a node at
level *l* is exactly a 6*l-bit Morton prefix and its 64 children are the 64
possible next-6-bit extensions.  An area is therefore a set of canonical
cells ≡ a set of aligned, disjoint Morton-key ranges.

This module represents an ``AreaTree`` in its *normalized range form*: a
sorted array of disjoint half-open uint64 key ranges ``[lo, hi)``.  The three
set operations the paper calls out (union, intersection, difference —
"combined in a fast, efficient manner") are linear merges over the range
lists; ``node_masks`` recovers the paper's per-node 64-bit child-occupancy
bitmask form, which is what the Pallas ``bitset`` kernel operates on at query
time (postings bitmaps use the same word-wise bit algebra).

Covers are built by recursive 64-way refinement with a vectorized
cell-classifier (OUT / FULL / PARTIAL), exactly the paper's construction for
points-with-radius, path strips, and polygonal regions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple

import numpy as np

from . import mercator as M

OUT, PARTIAL, FULL = 0, 1, 2
_U1 = np.uint64(1)
_KEY_SPACE = _U1 << np.uint64(60)

__all__ = ["AreaTree", "OUT", "PARTIAL", "FULL", "cover"]


def _merge_ranges(lo: np.ndarray, hi: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Sort + coalesce overlapping/adjacent [lo, hi) ranges."""
    if lo.size == 0:
        return lo, hi
    order = np.argsort(lo, kind="stable")
    lo, hi = lo[order], hi[order]
    # Running max of hi; a new group starts where lo > max(hi so far).
    run_hi = np.maximum.accumulate(hi)
    new_group = np.ones(lo.size, dtype=bool)
    new_group[1:] = lo[1:] > run_hi[:-1]
    group = np.cumsum(new_group) - 1
    n = group[-1] + 1
    out_lo = lo[new_group]
    out_hi = np.zeros(n, dtype=np.uint64)
    np.maximum.at(out_hi, group, hi)
    return out_lo, out_hi


@dataclass(frozen=True)
class AreaTree:
    """Normalized area: disjoint, sorted, half-open Morton-key ranges."""

    lo: np.ndarray  # uint64 [n]
    hi: np.ndarray  # uint64 [n]

    # ---------------------------------------------------------------- basics
    @staticmethod
    def empty() -> "AreaTree":
        z = np.zeros(0, dtype=np.uint64)
        return AreaTree(z, z.copy())

    @staticmethod
    def everything() -> "AreaTree":
        return AreaTree(np.array([0], dtype=np.uint64),
                        np.array([_KEY_SPACE], dtype=np.uint64))

    @staticmethod
    def from_ranges(lo, hi) -> "AreaTree":
        lo = np.asarray(lo, dtype=np.uint64).ravel()
        hi = np.asarray(hi, dtype=np.uint64).ravel()
        keep = hi > lo
        return AreaTree(*_merge_ranges(lo[keep], hi[keep]))

    @staticmethod
    def from_cells(cells, levels) -> "AreaTree":
        cells = np.asarray(cells, dtype=np.uint64).ravel()
        levels = np.broadcast_to(np.asarray(levels), cells.shape)
        sizes = _U1 << (np.uint64(6) * (np.uint64(M.MAX_LEVEL) - levels.astype(np.uint64)))
        return AreaTree.from_ranges(cells, cells + sizes)

    @property
    def is_empty(self) -> bool:
        return self.lo.size == 0

    def num_keys(self) -> int:
        return int(np.sum(self.hi - self.lo))

    def area_m2(self, lat_hint: float = 0.0) -> float:
        """Approximate ground area (m²); exact only near ``lat_hint``."""
        mpu = float(M.meters_per_unit_at(lat_hint))
        # One key = one level-10 cell = one (2^0)² block of the finest grid...
        # keys are spread over a 2^30×2^30 grid → each key covers one grid
        # cell of (METERS_PER_CELL·cos(lat))² only at level 10; a key range of
        # size s covers s cells of the finest grid.
        return self.num_keys() * mpu * mpu

    # ------------------------------------------------------------- set algebra
    def union(self, other: "AreaTree") -> "AreaTree":
        return AreaTree(*_merge_ranges(np.concatenate([self.lo, other.lo]),
                                       np.concatenate([self.hi, other.hi])))

    def intersect(self, other: "AreaTree") -> "AreaTree":
        a, b = self, other
        if a.is_empty or b.is_empty:
            return AreaTree.empty()
        # For every range in a, clip against b via searchsorted (vectorized
        # two-sided overlap): pair (i, j) overlaps iff a.lo < b.hi and b.lo < a.hi.
        lo_out, hi_out = [], []
        i = j = 0
        al, ah, bl, bh = a.lo, a.hi, b.lo, b.hi
        while i < al.size and j < bl.size:
            lo = max(al[i], bl[j])
            hi = min(ah[i], bh[j])
            if lo < hi:
                lo_out.append(lo)
                hi_out.append(hi)
            if ah[i] <= bh[j]:
                i += 1
            else:
                j += 1
        return AreaTree(np.array(lo_out, dtype=np.uint64),
                        np.array(hi_out, dtype=np.uint64))

    def difference(self, other: "AreaTree") -> "AreaTree":
        a, b = self, other
        if a.is_empty or b.is_empty:
            return AreaTree(a.lo.copy(), a.hi.copy())
        lo_out, hi_out = [], []
        j = 0
        for i in range(a.lo.size):
            cur = a.lo[i]
            end = a.hi[i]
            while j < b.lo.size and b.hi[j] <= cur:
                j += 1
            k = j
            while k < b.lo.size and b.lo[k] < end:
                if b.lo[k] > cur:
                    lo_out.append(cur)
                    hi_out.append(b.lo[k])
                cur = max(cur, b.hi[k])
                if cur >= end:
                    break
                k += 1
            if cur < end:
                lo_out.append(cur)
                hi_out.append(end)
        return AreaTree(np.array(lo_out, dtype=np.uint64),
                        np.array(hi_out, dtype=np.uint64))

    def intersects(self, other: "AreaTree") -> bool:
        return not self.intersect(other).is_empty

    # ------------------------------------------------------------- membership
    def contains(self, keys) -> np.ndarray:
        """Vectorized point membership for Morton ``keys`` → bool array."""
        keys = np.asarray(keys, dtype=np.uint64)
        if self.is_empty:
            return np.zeros(keys.shape, dtype=bool)
        idx = np.searchsorted(self.lo, keys, side="right") - 1
        ok = idx >= 0
        safe = np.where(ok, idx, 0)
        return ok & (keys < self.hi[safe])

    # ------------------------------------------------ canonical-cell views
    def to_cells(self) -> Tuple[np.ndarray, np.ndarray]:
        """Decompose into maximal canonical cells → (cell_ids, levels)."""
        cells, levels = [], []
        for lo, hi in zip(self.lo.tolist(), self.hi.tolist()):
            cur = lo
            while cur < hi:
                # Largest aligned block starting at cur that fits in [cur, hi).
                lev = M.MAX_LEVEL
                while lev > 0:
                    size = 1 << (6 * (M.MAX_LEVEL - lev + 1))
                    if cur % size == 0 and cur + size <= hi:
                        lev -= 1
                    else:
                        break
                size = 1 << (6 * (M.MAX_LEVEL - lev))
                cells.append(cur)
                levels.append(lev)
                cur += size
        return (np.array(cells, dtype=np.uint64),
                np.array(levels, dtype=np.int8))

    def node_masks(self, level: int):
        """Paper's 8×8 node form: {parent cell id at ``level-1`` → uint64 mask}.

        Bit *k* of the mask is set iff child *k* (the k-th 6-bit extension) is
        at least partially covered.  Used by tests for the bitset kernel and
        for interop with bitmap postings.
        """
        if level < 1:
            raise ValueError("level must be ≥ 1")
        shift = 6 * (M.MAX_LEVEL - level)
        parent_shift = 6 * (M.MAX_LEVEL - level + 1)
        masks: dict = {}
        for lo, hi in zip(self.lo.tolist(), self.hi.tolist()):
            c0 = lo >> shift                 # first covered child index
            c1 = (hi - 1) >> shift           # last covered child index
            for c in range(c0, c1 + 1):
                pidx = c >> 6                # parent index at level-1
                masks[pidx] = masks.get(pidx, 0) | (1 << (c & 63))
        return {np.uint64(p << parent_shift): np.uint64(m)
                for p, m in masks.items()}

    # ------------------------------------------------------------ convenience
    def __or__(self, o):
        return self.union(o)

    def __and__(self, o):
        return self.intersect(o)

    def __sub__(self, o):
        return self.difference(o)

    def __eq__(self, o):
        return (isinstance(o, AreaTree) and np.array_equal(self.lo, o.lo)
                and np.array_equal(self.hi, o.hi))

    # ------------------------------------------------------------ constructors
    @staticmethod
    def from_box(ix0: int, iy0: int, ix1: int, iy1: int,
                 max_level: int = 7) -> "AreaTree":
        """Cover the closed integer-Mercator rect [ix0,ix1]×[iy0,iy1]."""
        x0, x1 = sorted((int(ix0), int(ix1)))
        y0, y1 = sorted((int(iy0), int(iy1)))

        def classify(cx, cy, half):
            # cell box: [cx-half, cx+half) per axis
            lx, hx = cx - half, cx + half - 1
            ly, hy = cy - half, cy + half - 1
            outside = (hx < x0) | (lx > x1) | (hy < y0) | (ly > y1)
            inside = (lx >= x0) & (hx <= x1) & (ly >= y0) & (hy <= y1)
            return np.where(outside, OUT, np.where(inside, FULL, PARTIAL))

        return cover(classify, max_level)

    @staticmethod
    def from_circle(cx: int, cy: int, radius_units: float,
                    max_level: int = 7) -> "AreaTree":
        """Cover a circle (paper: point expanded by a confidence radius)."""
        cx, cy, r = float(cx), float(cy), float(radius_units)

        def classify(qx, qy, half):
            d = np.hypot(qx.astype(np.float64) - cx, qy.astype(np.float64) - cy)
            half_diag = half * np.sqrt(2.0)
            return np.where(d > r + half_diag, OUT,
                            np.where(d + half_diag <= r, FULL, PARTIAL))

        return cover(classify, max_level)

    @staticmethod
    def from_path(xs, ys, width_units: float, max_level: int = 7) -> "AreaTree":
        """Cover a polyline's envelope strip of half-width ``width_units``.

        This is the paper's probabilistic-path representation (Fig. 5/6): a
        curvilinear strip around the waypoints.  The cover is the union over
        per-segment capsules.
        """
        xs = np.asarray(xs, dtype=np.float64)
        ys = np.asarray(ys, dtype=np.float64)
        if xs.size == 1:
            return AreaTree.from_circle(xs[0], ys[0], width_units, max_level)
        ax, ay = xs[:-1], ys[:-1]
        bx, by = xs[1:], ys[1:]
        w = float(width_units)

        def classify(qx, qy, half):
            d = _point_segments_min_dist(qx.astype(np.float64),
                                         qy.astype(np.float64),
                                         ax, ay, bx, by)
            half_diag = half * np.sqrt(2.0)
            return np.where(d > w + half_diag, OUT,
                            np.where(d + half_diag <= w, FULL, PARTIAL))

        return cover(classify, max_level)

    @staticmethod
    def from_polygon(xs, ys, max_level: int = 7) -> "AreaTree":
        """Cover a simple polygon given integer-Mercator vertices."""
        xs = np.asarray(xs, dtype=np.float64)
        ys = np.asarray(ys, dtype=np.float64)
        ex0, ey0 = xs, ys
        ex1, ey1 = np.roll(xs, -1), np.roll(ys, -1)

        def classify(qx, qy, half):
            qx = qx.astype(np.float64)
            qy = qy.astype(np.float64)
            crosses = _segments_hit_boxes(ex0, ey0, ex1, ey1, qx, qy, half)
            center_in = _points_in_polygon(qx, qy, xs, ys)
            return np.where(crosses, PARTIAL, np.where(center_in, FULL, OUT))

        return cover(classify, max_level)


# --------------------------------------------------------------------------
# Recursive 64-way covering
# --------------------------------------------------------------------------

def cover(classify: Callable, max_level: int, *, conservative: bool = True
          ) -> AreaTree:
    """Build an area by 64-way refinement.

    ``classify(cx, cy, half)`` receives vectorized cell centers and half-edge
    (in integer-Mercator units, float) and returns OUT/PARTIAL/FULL per cell.
    PARTIAL cells at ``max_level`` are kept when ``conservative``.
    """
    if not 0 < max_level <= M.MAX_LEVEL:
        raise ValueError("max_level out of range")
    full_lo: list = []
    full_hi: list = []
    # Level-1 seed: the 64 children of the root.
    frontier = (np.arange(64, dtype=np.uint64)
                << np.uint64(6 * (M.MAX_LEVEL - 1)))
    level = 1
    while frontier.size:
        edge = np.uint64(1 << (30 - 3 * level))          # cell edge, units
        fx, fy = M.deinterleave(frontier)                 # min corner
        half = float(edge) / 2.0
        cx = fx.astype(np.float64) + half
        cy = fy.astype(np.float64) + half
        cls = np.asarray(classify(cx, cy, half))
        full = frontier[cls == FULL]
        if level == max_level and conservative:
            full = np.concatenate([full, frontier[cls == PARTIAL]])
        if full.size:
            size = _U1 << np.uint64(6 * (M.MAX_LEVEL - level))
            full_lo.append(full)
            full_hi.append(full + size)
        if level == max_level:
            break
        partial = frontier[cls == PARTIAL]
        if partial.size == 0:
            break
        child_shift = np.uint64(6 * (M.MAX_LEVEL - level - 1))
        kids = np.arange(64, dtype=np.uint64) << child_shift
        frontier = (partial[:, None] + kids[None, :]).ravel()
        level += 1
    if not full_lo:
        return AreaTree.empty()
    return AreaTree.from_ranges(np.concatenate(full_lo), np.concatenate(full_hi))


# --------------------------------------------------------------------------
# Vectorized geometry helpers (host-side numpy)
# --------------------------------------------------------------------------

def _point_segments_min_dist(qx, qy, ax, ay, bx, by):
    """Min distance from each query point to any segment (vectorized Q×S)."""
    dx = (bx - ax)[None, :]
    dy = (by - ay)[None, :]
    px = qx[:, None] - ax[None, :]
    py = qy[:, None] - ay[None, :]
    seg_len2 = np.maximum(dx * dx + dy * dy, 1e-12)
    t = np.clip((px * dx + py * dy) / seg_len2, 0.0, 1.0)
    ex = px - t * dx
    ey = py - t * dy
    return np.sqrt(ex * ex + ey * ey).min(axis=1)


def _points_in_polygon(qx, qy, vx, vy):
    """Ray-casting point-in-polygon, vectorized over query points."""
    inside = np.zeros(qx.shape, dtype=bool)
    n = vx.size
    j = n - 1
    for i in range(n):
        cond = ((vy[i] > qy) != (vy[j] > qy))
        # An edge only crosses the ray where `cond` holds, and there
        # |qy − vy[i]| < |denom|, so the quotient is bounded by the edge's
        # x-extent.  Degenerate/near-horizontal edges (cond all-False)
        # divide by the placeholder 1.0 instead — no overflow, result
        # masked out either way.
        denom = vy[j] - vy[i]
        xin = (vx[j] - vx[i]) * np.where(cond, qy - vy[i], 0.0) \
            / np.where(cond, denom, 1.0) + vx[i]
        inside ^= cond & (qx < xin)
        j = i
    return inside


def _segments_hit_boxes(ax, ay, bx, by, cx, cy, half):
    """Does any segment intersect each axis-aligned box? (slab test, Q×S)."""
    x0 = (cx - half)[:, None]
    x1 = (cx + half)[:, None]
    y0 = (cy - half)[:, None]
    y1 = (cy + half)[:, None]
    dx = (bx - ax)[None, :]
    dy = (by - ay)[None, :]
    axb = ax[None, :]
    ayb = ay[None, :]
    eps = 1e-12
    dxs = np.where(np.abs(dx) < eps, eps, dx)
    dys = np.where(np.abs(dy) < eps, eps, dy)
    tx1 = (x0 - axb) / dxs
    tx2 = (x1 - axb) / dxs
    ty1 = (y0 - ayb) / dys
    ty2 = (y1 - ayb) / dys
    tmin = np.maximum(np.minimum(tx1, tx2), np.minimum(ty1, ty2))
    tmax = np.minimum(np.maximum(tx1, tx2), np.maximum(ty1, ty2))
    # Degenerate axes: segment parallel to a slab → require inside that slab.
    para_x = np.abs(dx) < eps
    para_y = np.abs(dy) < eps
    in_x = (axb >= x0) & (axb <= x1)
    in_y = (ayb >= y0) & (ayb <= y1)
    hit = (tmax >= np.maximum(tmin, 0.0)) & (tmin <= 1.0)
    hit = np.where(para_x & ~in_x, False, hit)
    hit = np.where(para_y & ~in_y, False, hit)
    return hit.any(axis=1)
