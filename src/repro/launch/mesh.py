"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state — the dry-run driver must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and tests/benches must keep seeing the single real CPU
device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, CPU training)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
