"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state — the dry-run driver must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and tests/benches must keep seeing the single real CPU
device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh",
           "make_exec_mesh", "default_exec_partitions"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, CPU training)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


def make_exec_mesh(partitions: int = 0):
    """1-D ``"part"`` mesh for partitioned query execution.

    The axis spans ``min(partitions, len(jax.devices()))`` devices — on a
    one-device CPU host a P>1 query is *emulated*: the merge combine still
    runs under ``shard_map`` over this axis (size 1), so the partition
    code path and its launch/parity contracts never depend on the real
    device count.
    """
    n = len(jax.devices())
    size = min(max(1, int(partitions)) or n, n) if partitions else n
    return jax.make_mesh((max(size, 1),), ("part",))


def default_exec_partitions() -> int:
    """Mesh-derived default for ``core.planner.num_partitions``: one
    partition per available device."""
    return max(1, len(jax.devices()))
