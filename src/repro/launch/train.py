"""Training driver: checkpointed, restartable, elastic.

End-to-end trainer usable at two scales from the same code path:
  * CPU / tests: ``--arch <id> --reduced`` trains the reduced config for a
    few hundred steps (the examples/ml path),
  * production: the same pjit program the dry-run compiles, on a real mesh.

Fault-tolerance contract:
  * checkpoint every ``--ckpt_every`` steps (async, atomic, keep-last-k)
    including the data-pipeline state (seed, step) — restart replays
    nothing and loses at most one interval;
  * ``--resume`` restores the newest committed step, *resharding* onto the
    current mesh (elastic: restart on a different topology just works);
  * preemption-safe: SIGTERM finishes the in-flight step, saves, exits.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_0_5b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt_dir runs/ckpt
"""
from __future__ import annotations

import argparse
import signal
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..configs.base import get_config
from ..data.pipeline import TokenPipeline
from ..ml.model import ModelBundle, TrainConfig
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["train_loop", "main"]


def train_loop(arch: str, *, reduced: bool = True, steps: int = 200,
               batch: int = 8, seq: int = 128, lr: float = 1e-3,
               ckpt_dir: str | None = None, ckpt_every: int = 50,
               resume: bool = False, mesh=None, log_every: int = 10,
               seed: int = 0, loss_chunk: int | None = None,
               print_fn=print):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = mesh or make_local_mesh()
    tc = TrainConfig(lr=lr, warmup=min(20, steps // 10 + 1),
                     total_steps=steps, loss_chunk=loss_chunk,
                     remat="none" if reduced else "full")
    mb = ModelBundle(cfg, mesh, train_cfg=tc)

    params = mb.init_params(jax.random.key(seed))
    opt = mb.init_opt_state(params)
    pipe_state = {"seed": seed, "step": 0}
    start_step = 0

    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    if mgr is not None and resume:
        template = {"params": params, "opt": opt,
                    "data": {"seed": np.int64(seed), "step": np.int64(0)},
                    "step": np.int64(0)}
        restored, ck_step = mgr.restore_or_none(template)
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            pipe_state = {"seed": int(restored["data"]["seed"]),
                          "step": int(restored["data"]["step"])}
            start_step = int(restored["step"])
            print_fn(f"resumed from step {start_step}")

    pipe = TokenPipeline.restore(pipe_state, cfg.vocab_size, batch, seq)
    step_fn = jax.jit(mb.make_train_step(), donate_argnums=(0, 1))

    stop = {"now": False}
    old = signal.signal(signal.SIGTERM,
                        lambda *_: stop.__setitem__("now", True))

    losses = []
    t0 = time.perf_counter()
    try:
        for step in range(start_step, steps):
            data = next(pipe)
            batch_dev = {k: jnp.asarray(v) for k, v in data.items()}
            params, opt, metrics = step_fn(params, opt, batch_dev)
            if step % log_every == 0 or step == steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                dt = time.perf_counter() - t0
                print_fn(f"step {step:5d} loss {loss:8.4f} "
                         f"gnorm {float(metrics['grad_norm']):7.3f} "
                         f"lr {float(metrics['lr']):.2e} [{dt:6.1f}s]")
            if mgr is not None and ((step + 1) % ckpt_every == 0
                                    or stop["now"]):
                mgr.save(step + 1, {
                    "params": params, "opt": opt,
                    "data": {"seed": np.int64(pipe.seed),
                             "step": np.int64(pipe.step)},
                    "step": np.int64(step + 1)})
            if stop["now"]:
                print_fn(f"SIGTERM: checkpointed at {step + 1}, exiting")
                break
    finally:
        pipe.close()
        if mgr is not None:
            mgr.wait()
        signal.signal(signal.SIGTERM, old)
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt_dir", default=None)
    ap.add_argument("--ckpt_every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train_loop(args.arch, reduced=args.reduced, steps=args.steps,
               batch=args.batch, seq=args.seq, lr=args.lr,
               ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
               resume=args.resume, seed=args.seed)


if __name__ == "__main__":
    main()
