import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import/init: jax locks the device count on first
# use, and only the dry-run may see 512 placeholder host devices.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell, ``.lower().compile()``
the production pjit program — train_step for train shapes, prefill for
prefill shapes, serve_step (one token against a seq-len KV/state cache)
for decode shapes — on the 16×16 single-pod and 2×16×16 multi-pod meshes.

Prints ``memory_analysis()`` (proves the per-device footprint fits) and
``cost_analysis()`` (FLOPs/bytes for §Roofline), parses the post-SPMD HLO
for collective operand bytes, and writes one JSON artifact per cell under
``runs/dryrun/`` for ``benchmarks/roofline.py`` to aggregate.

Usage:
  python -m repro.launch.dryrun --arch qwen1_5_0_5b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi_pod both
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs.base import SHAPES, get_config, list_archs, shape_cells
from ..ml.model import ModelBundle, TrainConfig, input_specs
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str):
    """Sum operand bytes of every collective in the post-SPMD module.

    HLO operands are printed as bare ``%name`` references, so we first
    build a name → result-shape table, then look up each collective's
    operands; ``-start`` variants are counted once (their ``-done`` twin
    carries no new data).
    """
    shapes = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1).lstrip("%")] = m.group(2)
    per_kind = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    opnd_re = re.compile(r"\(([^)]*)\)")
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = next((k for k in _COLLECTIVES
                     if op == k or op == k + "-start"), None)
        if kind is None:
            continue
        # operand bytes: look up each %ref; fall back to result shape
        args = opnd_re.search(line.split(op, 1)[1])
        nbytes = 0
        if args:
            for ref in re.findall(r"%?([\w\.\-]+)", args.group(1)):
                if ref in shapes:
                    nbytes += _shape_bytes(shapes[ref])
        if nbytes == 0:
            nbytes = _shape_bytes(m.group(2))
        per_kind[kind]["count"] += 1
        per_kind[kind]["bytes"] += nbytes
    total = sum(v["bytes"] for v in per_kind.values())
    return {"per_kind": per_kind, "total_bytes": total}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "runs/dryrun", *,
             train_overrides: dict | None = None,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    if os.environ.get("REPRO_MOE_GROUP"):
        from dataclasses import replace as dc_replace
        cfg = dc_replace(cfg, moe_group_size=int(
            os.environ["REPRO_MOE_GROUP"]))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tc = TrainConfig(**(train_overrides or {}))
    mb = ModelBundle(cfg, mesh, impl="reference", train_cfg=tc)

    t0 = time.time()
    if shape.kind == "train":
        lowered = mb.lower_train(shape)
    elif shape.kind == "prefill":
        lowered = mb.lower_prefill(shape)
    else:
        lowered = mb.lower_decode(shape)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)          # legacy (no trip counts)
    analyzed = analyze_hlo(hlo)           # trip-count-aware (§Roofline)

    n_chips = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "axes": list(mesh.axis_names), "chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost": {
            "flops_per_device": cost.get("flops"),
            "bytes_per_device": cost.get("bytes accessed"),
        },
        "analyzed": analyzed,
        "collectives": coll,
        "model_flops_dense": 6 * cfg.params_count()
        * (shape.global_batch * (1 if shape.kind == "decode"
                                 else shape.seq_len)),
        "model_flops_active": 6 * cfg.active_params_count()
        * (shape.global_batch * (1 if shape.kind == "decode"
                                 else shape.seq_len)),
        "params": cfg.params_count(),
        "tag": tag,
    }
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multipod" if multi_pod else "pod"
    suffix = f"-{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}-{shape_name}-{mesh_tag}{suffix}.json")
    with open(path, "w") as fh:
        json.dump(record, fh, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi_pod", default="both",
                    choices=["true", "false", "both"])
    ap.add_argument("--out_dir", default="runs/dryrun")
    ap.add_argument("--tag", default="", help="artifact suffix (perf iters)")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--loss_chunk", type=int, default=2048)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--fsdp", default="false", choices=["true", "false"])
    ap.add_argument("--param_dtype", default="bfloat16")
    ap.add_argument("--no_zero1", action="store_true")
    ap.add_argument("--no_seq_parallel", action="store_true")
    ap.add_argument("--moe_group", type=int, default=None)
    ap.add_argument("--ssm_chunk", type=int, default=None)
    ap.add_argument("--keep_going", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    pods = {"true": [True], "false": [False],
            "both": [False, True]}[args.multi_pod]
    overrides = {"remat": args.remat, "loss_chunk": args.loss_chunk,
                 "zero1": not args.no_zero1, "fsdp": args.fsdp == "true",
                 "param_dtype": args.param_dtype,
                 "seq_parallel": not args.no_seq_parallel}
    if args.moe_group:
        os.environ["REPRO_MOE_GROUP"] = str(args.moe_group)
    if args.ssm_chunk:
        os.environ["REPRO_SSM_CHUNK"] = str(args.ssm_chunk)

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in shape_cells(cfg)]
                  if args.shape == "all" else [args.shape])
        for shape_name in shapes:
            for mp in pods:
                cell = f"{arch} × {shape_name} × " \
                       f"{'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape_name, mp, args.out_dir,
                                   train_overrides=overrides, tag=args.tag)
                    mem_gb = rec["memory"]["peak_bytes"] / 2**30 \
                        if rec["memory"]["peak_bytes"] else float("nan")
                    print(f"[OK]   {cell:58s} compile={rec['compile_s']:7.1f}s"
                          f" mem/dev={mem_gb:6.2f}GiB"
                          f" coll={rec['collectives']['total_bytes']/2**20:9.1f}MiB",
                          flush=True)
                except Exception as e:
                    failures.append((cell, repr(e)))
                    print(f"[FAIL] {cell}: {e}", flush=True)
                    if not args.keep_going:
                        traceback.print_exc()
                        raise
    if failures:
        print(f"\n{len(failures)} failures:")
        for cell, err in failures:
            print(f"  {cell}: {err[:200]}")
        raise SystemExit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
