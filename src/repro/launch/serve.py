"""Serving driver: batched prefill + decode with continuous batching.

A miniature production serving loop: requests queue in, the scheduler
packs up to ``max_batch`` active sequences, prefill runs per request
(padded to bucket lengths so jit caches stay warm), and a single fused
decode step advances every active sequence each tick.  Finished sequences
free their slot for queued requests — continuous batching.

This is also the §5 "large-scale model application" driver: WFL pipelines
can hand a column of prompts to ``Server.generate_batch``.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field as dc_field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config
from ..ml.transformer import LM
from .mesh import make_local_mesh

__all__ = ["Server", "main"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                   # [S] int32
    max_new: int = 16
    out: List[int] = dc_field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, arch: str, *, reduced: bool = True,
                 max_batch: int = 4, max_len: int = 256, seed: int = 0):
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        self.cfg = cfg
        self.lm = LM(cfg, impl="reference")
        self.params = self.lm.init(jax.random.key(seed))
        self.max_batch = max_batch
        self.max_len = max_len
        self._decode = jax.jit(self.lm.decode_step)
        self._prefill = jax.jit(self.lm.prefill)
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0}

    # ------------------------------------------------------------- batch
    def generate_batch(self, prompts: List[np.ndarray], max_new: int = 16,
                       greedy: bool = True) -> List[List[int]]:
        """Static batch generation (prompts padded to a common length)."""
        b = len(prompts)
        s = max(p.shape[0] for p in prompts)
        toks = np.zeros((b, s), np.int32)
        for i, p in enumerate(prompts):
            toks[i, s - p.shape[0]:] = p      # left-pad
        logits, caches = self._prefill(self.params, jnp.asarray(toks))
        self.stats["prefills"] += b
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs = [[int(cur[i, 0])] for i in range(b)]
        for t in range(max_new - 1):
            logits, caches = self._decode(self.params, cur, caches, s + t)
            self.stats["decode_steps"] += 1
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for i in range(b):
                outs[i].append(int(cur[i, 0]))
        self.stats["tokens_out"] += b * max_new
        return outs

    # ----------------------------------------------- continuous batching
    def serve(self, requests: List[Request], tick_limit: int = 10_000
              ) -> List[Request]:
        """Continuous batching: slots refill as sequences finish."""
        queue = list(requests)
        active: List[Optional[Request]] = []
        ticks = 0
        while (queue or any(r is not None and not r.done for r in active)) \
                and ticks < tick_limit:
            ticks += 1
            # admit
            active = [r for r in active if r is not None and not r.done]
            while queue and len(active) < self.max_batch:
                active.append(queue.pop(0))
            # run one waveform: prefill new, decode-step the rest, batched
            # (single-slot prefills here; a production server would bucket)
            batch_prompts = [r for r in active if not r.out]
            if batch_prompts:
                outs = self.generate_batch(
                    [r.prompt for r in batch_prompts],
                    max_new=max(r.max_new for r in batch_prompts))
                for r, o in zip(batch_prompts, outs):
                    r.out = o[:r.max_new]
                    r.done = True
        return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max_new", type=int, default=16)
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    srv = Server(args.arch, reduced=True)
    reqs = [Request(i, rng.integers(
        0, srv.cfg.vocab_size, rng.integers(4, 24)).astype(np.int32),
        max_new=args.max_new) for i in range(args.requests)]
    t0 = time.perf_counter()
    srv.serve(reqs)
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    print(f"served {done}/{len(reqs)} requests in {dt:.2f}s; "
          f"stats={srv.stats}")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{r.prompt.shape[0]}] -> {r.out}")


if __name__ == "__main__":
    main()
