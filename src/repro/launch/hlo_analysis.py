"""Trip-count-aware HLO cost analysis for the roofline terms.

XLA's built-in ``cost_analysis()`` visits a ``while`` body **once** — with
scan-over-layers that undercounts FLOPs, bytes and collectives by the
layer count (measured: ~7× for a 24-layer model).  This module parses the
post-SPMD, post-optimization HLO text (``compiled.as_text()``) into a call
graph and accumulates, per executed instruction × loop trip count:

  * **flops** — 2 · |output| · |contracted dims| for every ``dot`` (matmul
    flops dominate; elementwise ops are not counted — noted in §Roofline),
  * **bytes** — Σ (operand bytes + result bytes) over *fusion-level*
    instructions: post-fusion HLO is exactly the kernel granularity, so
    operands+results model HBM traffic far better than cost_analysis's
    per-op accounting,
  * **collective bytes** — operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Trip counts come from each while's condition computation (the
``compare(iter, constant)`` bound); unresolvable loops count once and are
reported in ``warnings``.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
def _parse_instr_line(line: str):
    """Manual parse of '  %name = <type> opcode(rest' lines.

    Regex is hopeless here: tuple result types span hundreds of chars and
    embed ``/*index=N*/`` comments (containing ``=``) and parens.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    # result type: balanced parens if tuple, else first token
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rest[:i + 1]
                    rest = rest[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:]
    par = rest.find("(")
    if par <= 0:
        return None
    op = rest[:par]
    if not re.fullmatch(r"[\w\-]+", op):
        return None
    return name, type_str, op, rest[par + 1:]
# computation header: "%name (args...) -> type {" — args may contain
# nested parens (tuple types), so only the leading name is parsed
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_META_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}
# Ops whose operands/results are charged as HBM traffic.  XLA:CPU leaves
# long chains of standalone converts/broadcasts that the TPU backend fuses
# into neighbors; charging only kernel-boundary ops models TPU HBM far
# better than per-instruction accounting (validated against arithmetic-
# intensity expectations in EXPERIMENTS §Roofline).
_BYTES_OPS = {"dot", "fusion", "convolution", "scatter", "gather",
              "dynamic-update-slice", "dynamic-slice", "reduce", "sort",
              "custom-call", "copy", "select-and-scatter", "concatenate",
              "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute", "pad", "reverse", "cholesky",
              "triangular-solve", "fft", "rng"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


class _Instr:
    __slots__ = ("name", "shape", "op", "rest")

    def __init__(self, name, shape, op, rest):
        self.name = name
        self.shape = shape
        self.op = op
        self.rest = rest


def _parse(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            comps[cur].append(_Instr(*parsed))
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands live before the closing paren of the call;
    # split on the paren that closes the argument list (naive but works
    # on XLA's printer, which never nests parens inside operand lists)
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = rest[:i]
                break
    else:
        args = rest
    return re.findall(r"%([\w\.\-]+)", args)


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=\{([^}]*)\}", rest)
    return m.group(1) if m else None


def _calls(rest: str) -> List[str]:
    """Computations referenced by this instruction (fusion/call/while)."""
    out = []
    for key in ("calls", "body", "condition", "to_apply",
                "true_computation", "false_computation"):
        m = re.search(key + r"=%?([\w\.\-]+)", rest)
        if m:
            out.append((key, m.group(1)))
    return out


def _dot_flops(instr: _Instr, shapes: Dict[str, str]) -> int:
    out = _shape_dims(instr.shape)
    if out is None:
        return 0
    _, out_dims = out
    ops = _operand_names(instr.rest)
    if not ops:
        return 0
    lhs_shape = shapes.get(ops[0])
    if lhs_shape is None:
        return 0
    lhs = _shape_dims(lhs_shape)
    if lhs is None:
        return 0
    _, lhs_dims = lhs
    contract = _attr(instr.rest, "lhs_contracting_dims")
    cdims = [int(x) for x in contract.split(",")] if contract else []
    k = 1
    for d in cdims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2 * n_out * k


def _trip_count(cond_instrs: List[_Instr]) -> Optional[int]:
    """Loop bound from the condition computation.

    jax scans lower to ``iter < N``; after fusion the compare may live in
    a wrapped fusion, so: prefer a constant consumed by a compare/fusion,
    fall back to the unique s32 constant of the (tiny) condition body.
    """
    consts: Dict[str, int] = {}
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.match(r"([\-\d]+)", ins.rest)
            if m and "s32" in ins.shape:
                consts[ins.name] = int(m.group(1))
    for ins in cond_instrs:
        if ins.op in ("compare", "fusion"):
            for op_name in _operand_names(ins.rest):
                if op_name in consts:
                    return max(consts[op_name], 0)
    if len(consts) == 1:
        return max(next(iter(consts.values())), 0)
    return None


def analyze_hlo(text: str) -> Dict:
    comps = _parse(text)
    shapes_per_comp = {c: {i.name: i.shape for i in instrs}
                       for c, instrs in comps.items()}
    warnings: List[str] = []
    memo: Dict[str, Dict] = {}

    def comp_cost(cname: str, stack=()) -> Dict:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in comps:
            return {"flops": 0, "bytes": 0, "scoped": 0,
                    "coll": {k: 0 for k in _COLLECTIVES},
                    "coll_count": {k: 0 for k in _COLLECTIVES}}
        shapes = shapes_per_comp[cname]
        flops = 0
        nbytes = 0
        scoped = 0                       # bytes inside flash_interior scope
        coll = {k: 0 for k in _COLLECTIVES}
        coll_count = {k: 0 for k in _COLLECTIVES}
        for ins in comps[cname]:
            calls = dict(_calls(ins.rest))
            if ins.op == "while":
                body = calls.get("body")
                cond = calls.get("condition")
                trips = _trip_count(comps.get(cond, [])) if cond else None
                if trips is None:
                    trips = 1
                    warnings.append(f"unresolved trip count in {cname}"
                                    f" ({ins.name})")
                for sub in (body, cond):
                    if sub:
                        c = comp_cost(sub, stack + (cname,))
                        flops += trips * c["flops"]
                        nbytes += trips * c["bytes"]
                        scoped += trips * c["scoped"]
                        for k in _COLLECTIVES:
                            coll[k] += trips * c["coll"][k]
                            coll_count[k] += trips * c["coll_count"][k]
                continue
            if ins.op in ("fusion", "call", "conditional", "map",
                          "reduce", "reduce-window", "sort", "scatter",
                          "custom-call", "select-and-scatter"):
                for key, sub in _calls(ins.rest):
                    c = comp_cost(sub, stack + (cname,))
                    flops += c["flops"]
                    # nested bytes NOT added: the fusion boundary is the
                    # kernel; its HBM traffic is counted below
                    for k in _COLLECTIVES:
                        coll[k] += c["coll"][k]
                        coll_count[k] += c["coll_count"][k]
            if ins.op == "dot":
                flops += _dot_flops(ins, shapes)
            kind = next((k for k in _COLLECTIVES
                         if ins.op == k or ins.op == k + "-start"), None)
            if kind:
                b = sum(_shape_bytes(shapes.get(o, ""))
                        for o in _operand_names(ins.rest))
                if b == 0:
                    b = _shape_bytes(ins.shape)
                coll[kind] += b
                coll_count[kind] += 1
            if ins.op in _BYTES_OPS and not ins.op.endswith("-done"):
                if ins.op == "dynamic-update-slice":
                    # in-place on TPU (buffer donation): traffic = the
                    # written slice, not 2× the full buffer (a one-token
                    # cache write was being charged 40 GiB)
                    ops_ = _operand_names(ins.rest)
                    b = 2 * _shape_bytes(shapes.get(ops_[1], ""))                         if len(ops_) > 1 else _shape_bytes(ins.shape)
                elif ins.op == "dynamic-slice":
                    b = 2 * _shape_bytes(ins.shape)   # read+write the slice
                elif ins.op in ("fusion", "custom-call"):
                    # heuristic: an operand >> the fusion's output is being
                    # sliced/gathered inside (scan xs reads, stacked-weight
                    # slices) - charge it at <=8x output, not full size.
                    # Without this a per-step slice of an [S,B,D] buffer
                    # bills the whole buffer every timestep (measured
                    # 800 TB of phantom traffic on the sLSTM scan).
                    b_out = _shape_bytes(ins.shape)
                    cap = max(8 * b_out, 1 << 20)
                    b = b_out + sum(
                        min(_shape_bytes(shapes.get(o, "")), cap)
                        for o in _operand_names(ins.rest))
                else:
                    b = _shape_bytes(ins.shape) + sum(
                        _shape_bytes(shapes.get(o, ""))
                        for o in _operand_names(ins.rest))
                nbytes += b
                # fusions of kernel-interior math (softmax chain): VMEM-
                # resident on the Pallas path — bucketed for the adjusted
                # memory term (dots stay charged: they stream q/k/v)
                if ins.op == "fusion" and ("flash_interior" in ins.rest
                        or "kernel_interior" in ins.rest):
                    scoped += b
        out = {"flops": flops, "bytes": nbytes, "scoped": scoped,
               "coll": coll, "coll_count": coll_count}
        memo[cname] = out
        return out

    # entry = the computation whose name the module header repeats; the
    # printer marks it ENTRY, which _parse stored like any other — find it
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: computation with the most instructions
        entry = max(comps, key=lambda c: len(comps[c]))
    cost = comp_cost(entry)
    return {
        "flops_per_device": cost["flops"],
        "bytes_per_device": cost["bytes"],
        "bytes_flash_interior": cost["scoped"],
        "collective_bytes": sum(cost["coll"].values()),
        "per_kind": {k: {"bytes": cost["coll"][k],
                         "count": cost["coll_count"][k]}
                     for k in _COLLECTIVES},
        "warnings": warnings[:20],
        "entry": entry,
    }
