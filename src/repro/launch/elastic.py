"""Elastic scaling: restart a job on a different mesh from checkpoints.

The mechanism is deliberately boring — that is the point: checkpoints are
mesh-agnostic (host npz + manifest), and ``restore_checkpoint`` re-places
leaves with the *target* shardings.  ``reshard_plan`` summarizes what a
scale-up/down changes (per-device bytes before/after) so an operator can
sanity-check a topology move; ``tests/test_checkpoint.py`` proves a train
state saved on mesh A restores bit-exactly onto mesh B.

At 1000+ nodes this is the recovery path for partial-pod loss: drain,
restart on the surviving slice (smaller data axis), restore, continue —
no resharding service needed because shard assembly happens at load.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import numpy as np

from ..ml.model import ModelBundle

__all__ = ["reshard_plan", "reroute_partitions"]


def reshard_plan(mb_from: ModelBundle, mb_to: ModelBundle) -> Dict:
    """Summarize a topology move for the same architecture."""
    shape = mb_from.params_shape()
    from_sh = mb_from.param_shardings()
    to_sh = mb_to.param_shardings()

    def per_device(leaf, sharding):
        n = int(np.prod([sharding.mesh.shape[a]
                         for spec_ax in (sharding.spec or ())
                         if spec_ax
                         for a in (spec_ax if isinstance(spec_ax, tuple)
                                   else (spec_ax,))])) or 1
        return int(np.prod(leaf.shape)) * leaf.dtype.itemsize / max(n, 1)

    before = sum(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(per_device, shape, from_sh)))
    after = sum(jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(per_device, shape, to_sh)))
    return {
        "from_mesh": dict(mb_from.mesh.shape),
        "to_mesh": dict(mb_to.mesh.shape),
        "param_bytes_per_device_before": int(before),
        "param_bytes_per_device_after": int(after),
        "ratio": after / max(before, 1),
    }


def reroute_partitions(parts: List[List[int]],
                       failed: Sequence[int]) -> List[List[int]]:
    """Partition-axis fault recovery for query execution.

    A partition that trips its FaultPlan check is drained and its shards
    are rerouted round-robin across the surviving partitions — the query
    still covers every shard, just on fewer devices (the engines re-sort
    partials by shard id before merging, so results are unchanged).  The
    partition count is preserved (failed slots become empty) so launch
    accounting stays per-slot.  With no survivors the original assignment
    is returned and the per-shard retry machinery takes over.
    """
    failed_set = {int(i) for i in failed}
    survivors = [i for i in range(len(parts)) if i not in failed_set]
    if not survivors:
        return [list(p) for p in parts]
    out: List[List[int]] = [list(p) if i in survivors else []
                            for i, p in enumerate(parts)]
    orphans = [sid for i in sorted(failed_set) if 0 <= i < len(parts)
               for sid in parts[i]]
    for j, sid in enumerate(orphans):
        out[survivors[j % len(survivors)]].append(sid)
    return out
